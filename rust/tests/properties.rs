//! Property-based tests over coordinator/packing/solver invariants, driven
//! by the in-crate property harness (`util::proptest`).

use camflow::cameras::{camera_at, scenarios, StreamKey, StreamRequest};
use camflow::catalog::{Catalog, Dims};
use camflow::coordinator::budget::{self, ComponentTelemetry};
use camflow::coordinator::expand::{self, PrevAssignment, PrevSlot};
use camflow::coordinator::shard::ShardedPlanner;
use camflow::coordinator::spot::{SpotPlanner, SpotPlannerConfig};
use camflow::coordinator::{Planner, PlannerConfig};
use camflow::packing::{BinType, ItemGroup, PackedBin, Packing, PackingProblem};
use camflow::geo::{self, cities, GeoPoint};
use camflow::packing::heuristic::{self, simple_problem};
use camflow::packing::mcvbp::{solve, solve_delta, DeltaHints, GhostGroup, PrevLayout, SolveOptions};
use camflow::packing::mcvbp::{pack_backfill, rehome_backfill, BackfillItem, LaneKind, TemporalLane};
use camflow::profiles::{Program, Resolution};
use camflow::solver::{
    solve_lp_dense_with_stats, solve_lp_partial_with_stats, solve_lp_with_stats, Eta,
    Factorization, Lp, LpOutcome, LpStats, Op,
};
use camflow::util::json;
use camflow::util::proptest::check;
use camflow::util::Rng;
use std::collections::BTreeSet;

/// Any feasible FFD packing respects headroom, covers every stream exactly
/// once, and the exact solver never costs more.
#[test]
fn prop_packing_invariants() {
    check(
        0xFACADE,
        60,
        |rng: &mut Rng| {
            // Flat encoding: triples of (cpu*100, mem*100, count).
            let groups = 1 + rng.index(4);
            let mut v = Vec::with_capacity(groups * 3);
            for _ in 0..groups {
                v.push((rng.range_f64(0.3, 6.5) * 100.0).round() as u64);
                v.push((rng.range_f64(0.3, 9.0) * 100.0).round() as u64);
                v.push(1 + rng.index(5) as u64);
            }
            v
        },
        |items: &Vec<u64>| {
            let spec: Vec<(f64, f64, usize)> = items
                .chunks_exact(3)
                .filter(|c| c[0] > 0 && c[1] > 0 && c[2] > 0)
                .map(|c| (c[0] as f64 / 100.0, c[1] as f64 / 100.0, c[2] as usize))
                .collect();
            if spec.is_empty() {
                return Ok(());
            }
            let p = simple_problem(
                &spec,
                &[(8.0, 15.0, 0.419), (16.0, 30.0, 0.796), (36.0, 60.0, 1.591)],
            );
            match heuristic::first_fit_decreasing(&p) {
                Err(_) => Ok(()), // infeasible is legal for oversized items
                Ok(ffd) => {
                    ffd.validate(&p).map_err(|e| format!("ffd invalid: {e}"))?;
                    if ffd.peak_utilization(&p) > p.headroom + 1e-9 {
                        return Err("headroom violated".into());
                    }
                    let (exact, stats) =
                        solve(&p, &SolveOptions::default()).map_err(|e| e.to_string())?;
                    exact.validate(&p).map_err(|e| format!("exact invalid: {e}"))?;
                    if stats.final_cost > ffd.total_cost(&p) + 1e-9 {
                        return Err(format!(
                            "exact {} worse than ffd {}",
                            stats.final_cost,
                            ffd.total_cost(&p)
                        ));
                    }
                    Ok(())
                }
            }
        },
    );
}

/// Plans assign each request exactly once and respect the hardware filter.
#[test]
fn prop_plan_assignment_invariants() {
    let catalog =
        Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
    check(
        0xBEEF,
        25,
        |rng: &mut Rng| {
            // Flat encoding: pairs of (is_vgg, fps*100 in the low Fig-3 regime).
            let n = 1 + rng.index(6);
            let mut v = Vec::with_capacity(n * 2);
            for _ in 0..n {
                v.push(rng.index(2) as u64);
                v.push((rng.range_f64(0.2, 1.2) * 100.0).round() as u64);
            }
            v
        },
        |spec: &Vec<u64>| {
            let requests: Vec<StreamRequest> = spec
                .chunks_exact(2)
                .filter(|c| c[1] > 0)
                .enumerate()
                .map(|(i, c)| {
                    StreamRequest::new(
                        camera_at(i as u64, "Chicago", cities::CHICAGO, Resolution::XGA, 30.0),
                        if c[0] == 1 { Program::Vgg16 } else { Program::Zf },
                        c[1] as f64 / 100.0,
                    )
                })
                .collect();
            if requests.is_empty() {
                return Ok(());
            }
            for cfg in [PlannerConfig::st1(), PlannerConfig::st2(), PlannerConfig::st3()] {
                let gpu_only = cfg.hardware == camflow::coordinator::HardwareFilter::GpuOnly;
                let cpu_only = cfg.hardware == camflow::coordinator::HardwareFilter::CpuOnly;
                let Ok(plan) = Planner::new(catalog.clone(), cfg).plan(&requests) else {
                    continue;
                };
                let mut seen = vec![0usize; requests.len()];
                for inst in &plan.instances {
                    if gpu_only && !inst.has_gpu {
                        return Err("ST2 placed a CPU instance".into());
                    }
                    if cpu_only && inst.has_gpu {
                        return Err("ST1 placed a GPU instance".into());
                    }
                    for &s in &inst.streams {
                        seen[s] += 1;
                    }
                }
                if seen.iter().any(|&c| c != 1) {
                    return Err(format!("bad assignment multiplicity {seen:?}"));
                }
            }
            Ok(())
        },
    );
}

/// Exact solve cost never exceeds either greedy packer's cost on the same
/// problem (FFD *and* the ARMVAC cheapest-first rule).
#[test]
fn prop_exact_cost_at_most_greedy_cost() {
    check(
        0xE4AC7,
        40,
        |rng: &mut Rng| {
            let groups = 1 + rng.index(3);
            let mut v = Vec::with_capacity(groups * 3);
            for _ in 0..groups {
                v.push((rng.range_f64(0.3, 6.0) * 100.0).round() as u64);
                v.push((rng.range_f64(0.3, 8.0) * 100.0).round() as u64);
                v.push(1 + rng.index(4) as u64);
            }
            v
        },
        |items: &Vec<u64>| {
            let spec: Vec<(f64, f64, usize)> = items
                .chunks_exact(3)
                .filter(|c| c[0] > 0 && c[1] > 0 && c[2] > 0)
                .map(|c| (c[0] as f64 / 100.0, c[1] as f64 / 100.0, c[2] as usize))
                .collect();
            if spec.is_empty() {
                return Ok(());
            }
            let p = simple_problem(
                &spec,
                &[(8.0, 15.0, 0.419), (16.0, 30.0, 0.796), (36.0, 60.0, 1.591)],
            );
            let Ok((exact, _)) = solve(&p, &SolveOptions::default()) else {
                return Ok(()); // infeasible is legal for oversized items
            };
            let exact_cost = exact.total_cost(&p);
            for greedy in [
                heuristic::first_fit_decreasing(&p),
                heuristic::armvac_fill(&p),
            ] {
                let greedy = greedy.map_err(|e| format!("greedy failed after exact: {e}"))?;
                if exact_cost > greedy.total_cost(&p) + 1e-9 {
                    return Err(format!(
                        "exact {exact_cost} > greedy {}",
                        greedy.total_cost(&p)
                    ));
                }
            }
            Ok(())
        },
    );
}

/// No packed bin exceeds the headroom-scaled capacity in ANY dimension, for
/// every packer (FFD, ARMVAC, exact) — the paper's 90% rule, checked
/// per-dimension rather than via the aggregate validator.
#[test]
fn prop_no_bin_exceeds_headroom_capacity_in_any_dimension() {
    check(
        0x90,
        40,
        |rng: &mut Rng| {
            let groups = 1 + rng.index(4);
            let mut v = Vec::with_capacity(groups * 3);
            for _ in 0..groups {
                v.push((rng.range_f64(0.2, 7.0) * 100.0).round() as u64);
                v.push((rng.range_f64(0.2, 12.0) * 100.0).round() as u64);
                v.push(1 + rng.index(5) as u64);
            }
            v
        },
        |items: &Vec<u64>| {
            let spec: Vec<(f64, f64, usize)> = items
                .chunks_exact(3)
                .filter(|c| c[0] > 0 && c[1] > 0 && c[2] > 0)
                .map(|c| (c[0] as f64 / 100.0, c[1] as f64 / 100.0, c[2] as usize))
                .collect();
            if spec.is_empty() {
                return Ok(());
            }
            let p = simple_problem(&spec, &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.8)]);
            let packings = [
                heuristic::first_fit_decreasing(&p).ok(),
                heuristic::armvac_fill(&p).ok(),
                solve(&p, &SolveOptions::default()).ok().map(|(pk, _)| pk),
            ];
            for packing in packings.into_iter().flatten() {
                for bin in &packing.bins {
                    let demand = bin.total_demand(&p);
                    let cap = p.effective_capacity(bin.bin_type);
                    for (d, c) in demand.as_array().iter().zip(cap.as_array()) {
                        if *d > c + 1e-9 {
                            return Err(format!(
                                "dimension overfull: demand {d} > headroom cap {c}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Incremental (warm-context) re-planning returns exactly the cold plan's
/// cost when the workload has not changed — the staged pipeline's caches
/// change how fast a plan is found, never which plan is found.
#[test]
fn prop_incremental_replan_cost_equals_cold_cost() {
    use camflow::coordinator::adaptive::AdaptiveManager;
    let catalog =
        Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
    check(
        0x1C3,
        15,
        |rng: &mut Rng| {
            let n = 1 + rng.index(5);
            let mut v = Vec::with_capacity(n * 2);
            for _ in 0..n {
                v.push(rng.index(2) as u64);
                v.push((rng.range_f64(0.2, 4.0) * 100.0).round() as u64);
            }
            v
        },
        |spec: &Vec<u64>| {
            let requests: Vec<StreamRequest> = spec
                .chunks_exact(2)
                .filter(|c| c[1] > 0)
                .enumerate()
                .map(|(i, c)| {
                    StreamRequest::new(
                        camera_at(i as u64, "Chicago", cities::CHICAGO, Resolution::XGA, 30.0),
                        if c[0] == 1 { Program::Vgg16 } else { Program::Zf },
                        c[1] as f64 / 100.0,
                    )
                })
                .collect();
            if requests.is_empty() {
                return Ok(());
            }
            let planner = Planner::new(catalog.clone(), PlannerConfig::st3());
            let Ok(cold) = planner.plan(&requests) else {
                return Ok(()); // infeasible workloads have no re-plan to compare
            };
            let mut mgr = AdaptiveManager::new(planner);
            mgr.replan(requests.clone()).map_err(|e| e.to_string())?;
            let report = mgr.replan(requests.clone()).map_err(|e| e.to_string())?;
            if !report.pipeline.warm_started {
                return Err("second identical re-plan did not warm-start".into());
            }
            let warm_cost = mgr.current_plan().unwrap().cost_per_hour;
            if (warm_cost - cold.cost_per_hour).abs() > 1e-9 {
                return Err(format!(
                    "incremental cost {warm_cost} != cold cost {}",
                    cold.cost_per_hour
                ));
            }
            Ok(())
        },
    );
}

/// Identical consecutive re-plans are churn-free end to end: the sticky
/// Expand moves no streams and keeps every slot, and `CloudSim::apply_plan`
/// backs the same slots with the same physical instance ids — zero
/// provisioning and zero terminations on the no-op re-plan.
#[test]
fn prop_identical_replan_is_churn_free_and_id_stable() {
    use camflow::cloudsim::CloudSim;
    use camflow::coordinator::adaptive::AdaptiveManager;
    let catalog =
        Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
    check(
        0x57_1C,
        15,
        |rng: &mut Rng| {
            let n = 1 + rng.index(6);
            let mut v = Vec::with_capacity(n * 2);
            for _ in 0..n {
                v.push(rng.index(2) as u64);
                v.push((rng.range_f64(0.2, 4.0) * 100.0).round() as u64);
            }
            v
        },
        |spec: &Vec<u64>| {
            let requests: Vec<StreamRequest> = spec
                .chunks_exact(2)
                .filter(|c| c[1] > 0)
                .enumerate()
                .map(|(i, c)| {
                    StreamRequest::new(
                        // Half the cameras collide on an id so fps tiers of
                        // the same camera+program are exercised too.
                        camera_at(i as u64 / 2, "Chicago", cities::CHICAGO, Resolution::XGA, 30.0),
                        if c[0] == 1 { Program::Vgg16 } else { Program::Zf },
                        c[1] as f64 / 100.0,
                    )
                })
                .collect();
            if requests.is_empty() {
                return Ok(());
            }
            let planner = Planner::new(catalog.clone(), PlannerConfig::st3());
            let mut mgr = AdaptiveManager::new(planner);
            if mgr.replan(requests.clone()).is_err() {
                return Ok(()); // infeasible workloads have nothing to re-plan
            }
            let mut sim = CloudSim::new(catalog.clone());
            let ids1 = sim.apply_plan(mgr.current_plan().unwrap()).map_err(|e| e.to_string())?;
            let report = mgr.replan(requests.clone()).map_err(|e| e.to_string())?;
            if report.streams_moved != 0 {
                return Err(format!("identical re-plan moved {} streams", report.streams_moved));
            }
            if report.streams_surviving != requests.len() {
                return Err(format!(
                    "expected {} surviving streams, accounting saw {}",
                    requests.len(),
                    report.streams_surviving
                ));
            }
            if !report.provision.is_empty() || !report.terminate.is_empty() {
                return Err(format!("identical re-plan changed the fleet: {report:?}"));
            }
            let alive_before = sim.alive().len();
            let ids2 = sim.apply_plan(mgr.current_plan().unwrap()).map_err(|e| e.to_string())?;
            if ids1 != ids2 {
                return Err(format!("instance ids not stable: {ids1:?} vs {ids2:?}"));
            }
            if sim.alive().len() != alive_before {
                return Err("no-op apply_plan provisioned or terminated instances".into());
            }
            // The sticky expansion still assigns every stream exactly once.
            let mut seen = vec![0usize; requests.len()];
            for inst in &mgr.current_plan().unwrap().instances {
                for &s in &inst.streams {
                    seen[s] += 1;
                }
            }
            if seen.iter().any(|&c| c != 1) {
                return Err(format!("bad assignment multiplicity {seen:?}"));
            }
            Ok(())
        },
    );
}

/// Delta-solve exactness: re-entering the solver from a cached basis and
/// branching order, after a randomized single-count demand perturbation,
/// returns the same cost a cold exact solve of the perturbed problem finds
/// (both proven optimal — the warm path's exactness guard falls back to the
/// cold path internally whenever a warm step cannot be certified).
#[test]
fn prop_delta_solve_from_warm_basis_matches_cold_exact_solve() {
    check(
        0xDE17A,
        30,
        |rng: &mut Rng| {
            let groups = 1 + rng.index(3);
            let mut v = Vec::with_capacity(groups * 3 + 2);
            for _ in 0..groups {
                v.push((rng.range_f64(0.4, 5.0) * 100.0).round() as u64);
                v.push((rng.range_f64(0.4, 7.0) * 100.0).round() as u64);
                v.push(2 + rng.index(5) as u64);
            }
            // Which group to perturb and in which direction.
            v.push(rng.index(groups) as u64);
            v.push(rng.index(2) as u64);
            v
        },
        |enc: &Vec<u64>| {
            let spec: Vec<(f64, f64, usize)> = enc[..enc.len() - 2]
                .chunks_exact(3)
                .map(|c| (c[0] as f64 / 100.0, c[1] as f64 / 100.0, c[2] as usize))
                .collect();
            let which = enc[enc.len() - 2] as usize % spec.len();
            let up = enc[enc.len() - 1] == 1;
            let bins = [(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)];
            let opts = SolveOptions::default();
            let base = simple_problem(&spec, &bins);
            let Ok((_, seed_stats)) = solve(&base, &opts) else {
                return Ok(()); // infeasible base is legal for oversized items
            };
            if !seed_stats.proven_optimal {
                return Ok(()); // nothing to replay without a proven seed
            }
            let hints = DeltaHints {
                root_basis: seed_stats.root_basis.clone(),
                branch_order: seed_stats.branch_order.clone(),
                ..DeltaHints::default()
            };
            let mut perturbed = spec.clone();
            perturbed[which].2 = if up {
                perturbed[which].2 + 1
            } else {
                (perturbed[which].2 - 1).max(1)
            };
            let p = simple_problem(&perturbed, &bins);
            let Ok((cold, cold_stats)) = solve(&p, &opts) else {
                return Ok(());
            };
            let (warm, warm_stats) =
                solve_delta(&p, &opts, None, None, Some(&hints)).map_err(|e| e.to_string())?;
            warm.validate(&p).map_err(|e| format!("warm packing invalid: {e}"))?;
            if !(cold_stats.proven_optimal && warm_stats.proven_optimal) {
                return Err("tiny perturbed instance failed to prove optimality".into());
            }
            let (wc, cc) = (warm.total_cost(&p), cold.total_cost(&p));
            if (wc - cc).abs() > 1e-9 {
                return Err(format!("delta-solve cost {wc} != cold exact cost {cc}"));
            }
            Ok(())
        },
    );
}

/// The revised simplex is held to the dense tableau **bit for bit** on
/// randomized LPs: identical outcome variants, and for optima bit-identical
/// objectives/solutions plus equal final bases. Both paths share the pivot
/// rules (EPS-windowed two-tier Dantzig, min-ratio ties broken on basic
/// variable ids) and one canonical finalization, so this is checkable with
/// `==` rather than tolerances. Coefficients live on a coarse 0.25 grid to
/// provoke degenerate ties, well away from the solver's ~1e-7 epsilon.
#[test]
fn prop_revised_simplex_matches_dense_bit_for_bit() {
    check(
        0x5147EF,
        60,
        |rng: &mut Rng| {
            let n = 1 + rng.index(6);
            let m = 1 + rng.index(5);
            let mut v = vec![n as u64, m as u64];
            for _ in 0..n {
                v.push(rng.index(17) as u64); // objective: (i-8)*0.5 in [-4, 4]
            }
            for _ in 0..m {
                v.push(rng.index(3) as u64); // op: Le / Ge / Eq
                v.push(rng.index(25) as u64); // rhs: i*0.5 in [0, 12]
                for _ in 0..n {
                    v.push(rng.index(9) as u64); // coeff: (i-2)*0.25 in [-0.5, 1.5]
                }
            }
            v
        },
        |enc: &Vec<u64>| {
            let (n, m) = (enc[0] as usize, enc[1] as usize);
            let mut lp = Lp::new(n);
            for j in 0..n {
                lp.set_objective(j, (enc[2 + j] as f64 - 8.0) * 0.5);
            }
            let mut at = 2 + n;
            for _ in 0..m {
                let op = match enc[at] {
                    0 => Op::Le,
                    1 => Op::Ge,
                    _ => Op::Eq,
                };
                let rhs = enc[at + 1] as f64 * 0.5;
                let coeffs: Vec<(usize, f64)> = (0..n)
                    .filter_map(|j| {
                        let c = (enc[at + 2 + j] as f64 - 2.0) * 0.25;
                        (c != 0.0).then_some((j, c))
                    })
                    .collect();
                lp.add_constraint(coeffs, op, rhs);
                at += 2 + n;
            }
            let dense = solve_lp_dense_with_stats(&lp, &mut LpStats::default())
                .map_err(|e| format!("dense solve failed: {e}"))?;
            let revised = solve_lp_with_stats(&lp, &mut LpStats::default())
                .map_err(|e| format!("revised solve failed: {e}"))?;
            match (&dense, &revised) {
                (LpOutcome::Optimal(d), LpOutcome::Optimal(r)) => {
                    if d.objective.to_bits() != r.objective.to_bits() {
                        return Err(format!(
                            "objective bits differ: dense {} vs revised {}",
                            d.objective, r.objective
                        ));
                    }
                    if d.x.len() != r.x.len()
                        || d.x.iter().zip(&r.x).any(|(a, b)| a.to_bits() != b.to_bits())
                    {
                        return Err(format!("solutions differ: {:?} vs {:?}", d.x, r.x));
                    }
                    if d.basis != r.basis {
                        return Err(format!(
                            "final bases differ: {:?} vs {:?}",
                            d.basis, r.basis
                        ));
                    }
                    Ok(())
                }
                (LpOutcome::Infeasible, LpOutcome::Infeasible)
                | (LpOutcome::Unbounded, LpOutcome::Unbounded) => Ok(()),
                _ => Err(format!(
                    "outcome variants differ: dense {dense:?} vs revised {revised:?}"
                )),
            }
        },
    );
}

/// Compacted eta storage (one flat arena plus identity-eta elision) is a
/// layout change only: FTRAN and BTRAN through a [`Factorization`] driven
/// by random pivot sequences must match an append-only `Vec<Eta>` replay of
/// the same pivots bit-for-bit — including sequences with unit-column
/// pivots, which the compacted file elides entirely.
#[test]
fn prop_compacted_eta_matches_reference() {
    const EPS: f64 = 1e-9; // mirrors the factorization's drop tolerance
    check(
        0xE7AF17E,
        40,
        |rng: &mut Rng| {
            let m = 2 + rng.index(7);
            let pivots = 1 + rng.index(24);
            let mut v = vec![m as u64, pivots as u64];
            for _ in 0..pivots {
                v.push(rng.index(m) as u64); // pivot position
                v.push(rng.index(4) as u64); // 0 = unit column (identity eta)
                for _ in 0..m {
                    // Column entries in milli units; ~1/3 exact zeros.
                    let z = if rng.index(3) == 0 {
                        0
                    } else {
                        (rng.range_f64(-4.0, 4.0) * 1000.0).round() as i64
                    };
                    v.push(z as u64);
                }
            }
            for _ in 0..m {
                v.push((rng.range_f64(-9.0, 9.0) * 1000.0).round() as i64 as u64);
            }
            v
        },
        |enc: &Vec<u64>| {
            let m = enc[0] as usize;
            let pivots = enc[1] as usize;
            let mut at = 2;
            let mut fact = Factorization::identity(m);
            let mut reference: Vec<Eta> = Vec::new();
            for _ in 0..pivots {
                let p = enc[at] as usize;
                let unit = enc[at + 1] == 0;
                let mut z: Vec<f64> = enc[at + 2..at + 2 + m]
                    .iter()
                    .map(|&u| u as i64 as f64 / 1000.0)
                    .collect();
                at += 2 + m;
                if unit {
                    // Exact unit column at the pivot row: the eta is an
                    // exact identity the compacted file elides.
                    z = vec![0.0; m];
                    z[p] = 1.0;
                }
                // No refactorization happens here, so position p pivots in
                // internal row p on both sides.
                let accepted = fact.update(p, &z);
                if z[p].abs() <= EPS {
                    if accepted {
                        return Err(format!("pivot {} accepted below EPS", z[p]));
                    }
                    continue;
                }
                if !accepted {
                    return Err(format!("pivot {} rejected above EPS", z[p]));
                }
                // Append-only reference: the same entry filter, no elision.
                let entries: Vec<(usize, f64)> = z
                    .iter()
                    .enumerate()
                    .filter(|&(i, v)| i != p && v.abs() >= EPS)
                    .map(|(i, &v)| (i, v))
                    .collect();
                reference.push(Eta { row: p, inv: 1.0 / z[p], entries });
            }
            let probe: Vec<f64> = enc[at..at + m]
                .iter()
                .map(|&u| u as i64 as f64 / 1000.0)
                .collect();

            let mut ftran_fact = probe.clone();
            fact.ftran(&mut ftran_fact);
            let mut ftran_ref = probe.clone();
            for e in &reference {
                e.apply(&mut ftran_ref);
            }
            if ftran_fact
                .iter()
                .zip(&ftran_ref)
                .any(|(a, b)| a.to_bits() != b.to_bits())
            {
                return Err(format!("FTRAN differs: {ftran_fact:?} vs {ftran_ref:?}"));
            }

            let mut btran_fact = probe.clone();
            fact.btran(&mut btran_fact);
            let mut btran_ref = probe;
            for e in reference.iter().rev() {
                e.apply_transposed(&mut btran_ref);
            }
            if btran_fact
                .iter()
                .zip(&btran_ref)
                .any(|(a, b)| a.to_bits() != b.to_bits())
            {
                return Err(format!("BTRAN differs: {btran_fact:?} vs {btran_ref:?}"));
            }
            Ok(())
        },
    );
}

/// Partial-pricing mode must agree with the dense reference on outcome
/// variant and, for optimal instances, on the objective to ≤ 1e-9 — the
/// certification the mode's final full pricing sweep provides in place of
/// full-Dantzig's bit parity.
#[test]
fn prop_partial_pricing_matches_dense_objective() {
    check(
        0x9A127A1,
        60,
        |rng: &mut Rng| {
            let n = 2 + rng.index(10);
            let m = 1 + rng.index(6);
            let mut v = vec![n as u64, m as u64];
            for _ in 0..n {
                v.push((rng.range_f64(0.2, 5.0) * 100.0).round() as u64);
            }
            for _ in 0..m {
                v.push(rng.index(2) as u64); // op: 0 = Ge, 1 = Le
                v.push((rng.range_f64(1.0, 12.0) * 100.0).round() as u64);
                for _ in 0..n {
                    let c = if rng.index(3) == 0 {
                        0
                    } else {
                        (rng.range_f64(0.1, 3.0) * 100.0).round() as i64
                    };
                    v.push(c as u64);
                }
            }
            v
        },
        |enc: &Vec<u64>| {
            let n = enc[0] as usize;
            let m = enc[1] as usize;
            let mut lp = Lp::new(n);
            for (j, &c) in enc[2..2 + n].iter().enumerate() {
                lp.set_objective(j, c as f64 / 100.0);
            }
            let mut at = 2 + n;
            for _ in 0..m {
                let op = if enc[at] == 0 { Op::Ge } else { Op::Le };
                let rhs = enc[at + 1] as f64 / 100.0;
                let coeffs: Vec<(usize, f64)> = enc[at + 2..at + 2 + n]
                    .iter()
                    .enumerate()
                    .filter(|&(_, &c)| c != 0)
                    .map(|(j, &c)| (j, c as i64 as f64 / 100.0))
                    .collect();
                at += 2 + n;
                if coeffs.is_empty() {
                    continue;
                }
                lp.add_constraint(coeffs, op, rhs);
            }
            let dense = solve_lp_dense_with_stats(&lp, &mut LpStats::default())
                .map_err(|e| format!("dense solve failed: {e}"))?;
            let partial = solve_lp_partial_with_stats(&lp, &mut LpStats::default())
                .map_err(|e| format!("partial solve failed: {e}"))?;
            match (&dense, &partial) {
                (LpOutcome::Optimal(d), LpOutcome::Optimal(r)) => {
                    if (d.objective - r.objective).abs() > 1e-9 {
                        return Err(format!(
                            "objectives differ: dense {} vs partial {}",
                            d.objective, r.objective
                        ));
                    }
                    Ok(())
                }
                (LpOutcome::Infeasible, LpOutcome::Infeasible)
                | (LpOutcome::Unbounded, LpOutcome::Unbounded) => Ok(()),
                _ => Err(format!(
                    "outcome variants differ: dense {dense:?} vs partial {partial:?}"
                )),
            }
        },
    );
}

/// Structural delta-solve is certified-or-cold in every direction: dropping
/// a whole group from a solved instance (ghost embedding), adding one to it
/// (block-translated basis), or swapping one for another in a single
/// re-plan (ghost + translation mixed) must reproduce the cold exact cost
/// whenever both sides prove optimality.
#[test]
fn prop_structural_delta_solve_matches_cold_exact_solve() {
    check(
        0x57D317A,
        20,
        |rng: &mut Rng| {
            let groups = 2 + rng.index(2);
            let mut v = Vec::with_capacity(groups * 3 + 4);
            for _ in 0..groups {
                v.push((rng.range_f64(0.4, 5.0) * 100.0).round() as u64);
                v.push((rng.range_f64(0.4, 7.0) * 100.0).round() as u64);
                v.push(2 + rng.index(5) as u64);
            }
            // A replacement group for the mixed direction...
            v.push((rng.range_f64(0.4, 5.0) * 100.0).round() as u64);
            v.push((rng.range_f64(0.4, 7.0) * 100.0).round() as u64);
            v.push(2 + rng.index(5) as u64);
            v.push(rng.index(groups) as u64); // ...and the group it swaps for
            v
        },
        |enc: &Vec<u64>| {
            let spec: Vec<(f64, f64, usize)> = enc[..enc.len() - 4]
                .chunks_exact(3)
                .map(|c| (c[0] as f64 / 100.0, c[1] as f64 / 100.0, c[2] as usize))
                .collect();
            let repl = &enc[enc.len() - 4..enc.len() - 1];
            let repl = (repl[0] as f64 / 100.0, repl[1] as f64 / 100.0, repl[2] as usize);
            let which = enc[enc.len() - 1] as usize % spec.len();
            let smaller_spec: Vec<(f64, f64, usize)> = spec
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != which)
                .map(|(_, s)| *s)
                .collect();
            let bins = [(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)];
            let opts = SolveOptions::default();
            let base = simple_problem(&spec, &bins);
            let smaller = simple_problem(&smaller_spec, &bins);

            let ghost_of = |p: &PackingProblem, g: usize, at: usize| GhostGroup {
                position: at,
                demand_bits: p.items[g]
                    .demand_per_bin
                    .iter()
                    .map(|d| d.map(|dims| dims.as_array().map(f64::to_bits)))
                    .collect(),
                count: p.items[g].count,
            };

            // Vanished: `base` is the cached solve, `smaller` re-plans warm
            // through the ghost embedding of the dropped group.
            if let Ok((_, big_st)) = solve(&base, &opts) {
                if big_st.proven_optimal && big_st.root_basis.is_some() {
                    let hints = DeltaHints {
                        root_basis: big_st.root_basis.clone(),
                        branch_order: big_st.branch_order.clone(),
                        ghosts: vec![ghost_of(&base, which, which)],
                        appeared: None,
                    };
                    if let Ok((cold, cold_st)) = solve(&smaller, &opts) {
                        let (warm, warm_st) =
                            solve_delta(&smaller, &opts, None, None, Some(&hints))
                                .map_err(|e| e.to_string())?;
                        warm.validate(&smaller)
                            .map_err(|e| format!("ghost warm packing invalid: {e}"))?;
                        if cold_st.proven_optimal && warm_st.proven_optimal {
                            let (wc, cc) = (warm.total_cost(&smaller), cold.total_cost(&smaller));
                            if (wc - cc).abs() > 1e-9 {
                                return Err(format!("ghost warm cost {wc} != cold {cc}"));
                            }
                        }
                    }

                    // Mixed: group `which` swaps for the replacement group
                    // in one re-plan — the vanished group re-embeds as a
                    // ghost at its old slot and the cached basis translates
                    // around the appeared group (at augmented index
                    // `which + 1`, right after its ghost).
                    let mut swapped_spec = spec.clone();
                    swapped_spec[which] = repl;
                    let swapped = simple_problem(&swapped_spec, &bins);
                    let hints = DeltaHints {
                        root_basis: None,
                        branch_order: Vec::new(),
                        ghosts: vec![ghost_of(&base, which, which)],
                        appeared: big_st.root_basis.clone().map(|basis| PrevLayout {
                            basis,
                            blocks: big_st.var_blocks.clone(),
                            num_vars: big_st.milp_vars,
                            num_groups: spec.len(),
                            new_groups: vec![which + 1],
                        }),
                    };
                    if let Ok((cold, cold_st)) = solve(&swapped, &opts) {
                        let (warm, warm_st) =
                            solve_delta(&swapped, &opts, None, None, Some(&hints))
                                .map_err(|e| e.to_string())?;
                        warm.validate(&swapped)
                            .map_err(|e| format!("mixed warm packing invalid: {e}"))?;
                        if cold_st.proven_optimal && warm_st.proven_optimal {
                            let (wc, cc) = (warm.total_cost(&swapped), cold.total_cost(&swapped));
                            if (wc - cc).abs() > 1e-9 {
                                return Err(format!("mixed warm cost {wc} != cold {cc}"));
                            }
                        }
                    }
                }
            }

            // Appeared: `smaller` is the cached solve, `base` re-plans warm
            // through the block-translated basis.
            if let Ok((_, small_st)) = solve(&smaller, &opts) {
                if small_st.proven_optimal {
                    if let Some(basis) = small_st.root_basis.clone() {
                        let hints = DeltaHints {
                            root_basis: None,
                            branch_order: Vec::new(),
                            ghosts: Vec::new(),
                            appeared: Some(PrevLayout {
                                basis,
                                blocks: small_st.var_blocks.clone(),
                                num_vars: small_st.milp_vars,
                                num_groups: smaller.items.len(),
                                new_groups: vec![which],
                            }),
                        };
                        if let Ok((cold, cold_st)) = solve(&base, &opts) {
                            let (warm, warm_st) =
                                solve_delta(&base, &opts, None, None, Some(&hints))
                                    .map_err(|e| e.to_string())?;
                            warm.validate(&base)
                                .map_err(|e| format!("translated warm packing invalid: {e}"))?;
                            if cold_st.proven_optimal && warm_st.proven_optimal {
                                let (wc, cc) = (warm.total_cost(&base), cold.total_cost(&base));
                                if (wc - cc).abs() > 1e-9 {
                                    return Err(format!("translated warm cost {wc} != cold {cc}"));
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Budget adaptation never allocates any component less than the static
/// seed budget, grants never exceed the donated pool when it is
/// oversubscribed, and a hard component with donors present always gets a
/// strictly larger budget.
#[test]
fn prop_budget_allocation_floors_at_the_static_seed() {
    check(
        0xB06E7,
        60,
        |rng: &mut Rng| {
            let n = 1 + rng.index(8);
            let mut v = vec![n as u64];
            for _ in 0..n {
                v.push(rng.index(3) as u64); // 0 = no history, 1 = easy, 2 = hard
                v.push(rng.index(20_000) as u64); // usage
            }
            v
        },
        |enc: &Vec<u64>| {
            let n = enc[0] as usize;
            let static_opts = SolveOptions::default();
            let telemetry: Vec<Option<ComponentTelemetry>> = (0..n)
                .map(|i| {
                    let kind = enc[1 + i * 2];
                    let usage = enc[2 + i * 2] as usize;
                    match kind {
                        0 => None,
                        1 => Some(ComponentTelemetry {
                            graph_nodes: usage,
                            milp_vars: usage / 10,
                            milp_nodes: usage / 10,
                            exact: true,
                            proven: true,
                            budget_exhausted: false,
                            graph_budget: static_opts.max_graph_nodes,
                            var_budget: static_opts.max_milp_vars,
                            node_budget: static_opts.milp.max_nodes,
                        }),
                        _ => Some(ComponentTelemetry {
                            graph_nodes: usage,
                            exact: false,
                            budget_exhausted: true,
                            graph_budget: static_opts.max_graph_nodes,
                            var_budget: static_opts.max_milp_vars,
                            node_budget: static_opts.milp.max_nodes,
                            ..Default::default()
                        }),
                    }
                })
                .collect();
            let history: Vec<Option<&ComponentTelemetry>> =
                telemetry.iter().map(Option::as_ref).collect();
            let out = budget::allocate(&static_opts, &history);
            if out.len() != n {
                return Err("allocation count mismatch".into());
            }
            let mut donors = false;
            let mut hard = Vec::new();
            for (i, t) in telemetry.iter().enumerate() {
                match t {
                    Some(t) if t.is_hard() => hard.push(i),
                    Some(t) => {
                        // Margin of 100 so even a maximally oversubscribed
                        // pool still rounds every proportional grant ≥ 1.
                        donors |= t.graph_nodes * 2 + 100 <= static_opts.max_graph_nodes;
                    }
                    None => {}
                }
            }
            for (i, o) in out.iter().enumerate() {
                if o.max_graph_nodes < static_opts.max_graph_nodes
                    || o.max_milp_vars < static_opts.max_milp_vars
                    || o.milp.max_nodes < static_opts.milp.max_nodes
                {
                    return Err(format!("component {i} allocated below the static floor"));
                }
            }
            if donors {
                for &i in &hard {
                    if out[i].max_graph_nodes <= static_opts.max_graph_nodes {
                        return Err(format!(
                            "hard component {i} got no grant despite pool slack"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Pooled budget allocation extends the PR-3 floor property across
/// planning contexts: with a random external (cross-candidate) share,
/// every component still floors at the static seed, every pooled budget
/// dominates the isolated allocation on every axis (donation can only
/// add), a zero external share reproduces `allocate` exactly, and the
/// published donation never exceeds what this round's own donors left.
#[test]
fn prop_budget_pool_never_floors_below_seed_and_dominates_isolated() {
    use camflow::coordinator::budget::{allocate_pooled, AxisSlack};
    check(
        0xB07ED,
        60,
        |rng: &mut Rng| {
            let n = 1 + rng.index(8);
            let mut v = vec![n as u64];
            for _ in 0..n {
                v.push(rng.index(3) as u64); // 0 = no history, 1 = easy, 2 = hard
                v.push(rng.index(20_000) as u64); // usage
            }
            v.push(rng.index(50_000) as u64); // external graph-node share
            v
        },
        |enc: &Vec<u64>| {
            let Some(&n) = enc.first() else { return Ok(()) };
            let n = n as usize;
            if enc.len() < 2 + 2 * n {
                return Ok(()); // shrunk encoding, nothing to check
            }
            let static_opts = SolveOptions::default();
            let telemetry: Vec<Option<ComponentTelemetry>> = (0..n)
                .map(|i| {
                    let kind = enc[1 + i * 2];
                    let usage = enc[2 + i * 2] as usize;
                    match kind {
                        0 => None,
                        1 => Some(ComponentTelemetry {
                            graph_nodes: usage,
                            milp_vars: usage / 10,
                            milp_nodes: usage / 10,
                            exact: true,
                            proven: true,
                            budget_exhausted: false,
                            graph_budget: static_opts.max_graph_nodes,
                            var_budget: static_opts.max_milp_vars,
                            node_budget: static_opts.milp.max_nodes,
                        }),
                        _ => Some(ComponentTelemetry {
                            graph_nodes: usage,
                            exact: false,
                            budget_exhausted: true,
                            graph_budget: static_opts.max_graph_nodes,
                            var_budget: static_opts.max_milp_vars,
                            node_budget: static_opts.milp.max_nodes,
                            ..Default::default()
                        }),
                    }
                })
                .collect();
            let history: Vec<Option<&ComponentTelemetry>> =
                telemetry.iter().map(Option::as_ref).collect();
            let external =
                AxisSlack { graph_nodes: enc[enc.len() - 1] as usize, ..AxisSlack::default() };
            let iso = budget::allocate(&static_opts, &history);
            let pooled = allocate_pooled(&static_opts, &history, external);
            if pooled.opts.len() != n || pooled.drawn_nodes.len() != n {
                return Err("allocation count mismatch".into());
            }
            let mut donor_slack = 0usize;
            for t in telemetry.iter().flatten() {
                if !t.is_hard() {
                    donor_slack += static_opts
                        .max_graph_nodes
                        .saturating_sub(t.graph_nodes.saturating_mul(2));
                }
            }
            for (i, (p, s)) in pooled.opts.iter().zip(&iso).enumerate() {
                if p.max_graph_nodes < static_opts.max_graph_nodes
                    || p.max_milp_vars < static_opts.max_milp_vars
                    || p.milp.max_nodes < static_opts.milp.max_nodes
                {
                    return Err(format!("component {i} allocated below the static floor"));
                }
                if p.max_graph_nodes < s.max_graph_nodes
                    || p.max_milp_vars < s.max_milp_vars
                    || p.milp.max_nodes < s.milp.max_nodes
                {
                    return Err(format!(
                        "pooled allocation below isolated for component {i}: \
                         pooled {} vs isolated {}",
                        p.max_graph_nodes, s.max_graph_nodes
                    ));
                }
                if p.max_graph_nodes != s.max_graph_nodes + pooled.drawn_nodes[i] {
                    return Err(format!("draw accounting broken for component {i}"));
                }
            }
            if pooled.published.graph_nodes > donor_slack {
                return Err(format!(
                    "published {} exceeds donor slack {donor_slack}",
                    pooled.published.graph_nodes
                ));
            }
            // A zero external share must reproduce `allocate` bit for bit.
            let zero = allocate_pooled(&static_opts, &history, AxisSlack::default());
            for (a, b) in zero.opts.iter().zip(&iso) {
                if a.max_graph_nodes != b.max_graph_nodes
                    || a.max_milp_vars != b.max_milp_vars
                    || a.milp.max_nodes != b.milp.max_nodes
                {
                    return Err("zero-external pooled allocation diverged from allocate".into());
                }
            }
            if zero.drawn_nodes.iter().any(|&d| d != 0) {
                return Err("zero-external allocation cannot draw".into());
            }
            Ok(())
        },
    );
}

/// Portfolio winner flips preserve the deployed assignment: randomized
/// Fig-3-S1-shaped workloads where a price perturbation forces the GCL
/// portfolio's winner to flip to the nearest-exact candidate on an
/// *unchanged* workload. The flipped-to plan is shape-identical to the
/// deployed one, so `streams_moved` must count only the packing diff —
/// zero — and the simulator must keep identical `InstanceId`s with zero
/// provision/terminate across the flip.
#[test]
fn prop_winner_flip_preserves_assignment() {
    // The scenario pieces (priced two-region catalog, S1 demand shape,
    // probe calibration) are the bench's own (`camflow::bench::portfolio`),
    // so the property and `bench_adaptive`'s portfolio section cannot
    // drift apart.
    use camflow::bench::portfolio::{calibrated_budget, flip_catalog, s1_workload};
    use camflow::cloudsim::CloudSim;
    use camflow::coordinator::adaptive::AdaptiveManager;
    use camflow::coordinator::portfolio::Candidate;
    check(
        0xF11B,
        6,
        |rng: &mut Rng| {
            vec![
                2 + rng.index(2) as u64,                           // n_zf in 2..=3
                rng.next_u64(),                                    // departure pick
                (rng.range_f64(2.0, 8.0) * 1000.0).round() as u64, // expensive c4
                (rng.range_f64(0.36, 0.50) * 1000.0).round() as u64, // cheap c4
            ]
        },
        |enc: &Vec<u64>| {
            if enc.len() < 4 {
                return Ok(()); // shrunk encoding, nothing to check
            }
            let n_zf = enc[0] as usize;
            let expensive = enc[2] as f64 / 1000.0;
            let cheap = enc[3] as f64 / 1000.0;
            if !(2..=3).contains(&n_zf) || !(1.0..=10.0).contains(&expensive)
                || !(0.36..=0.50).contains(&cheap)
            {
                return Ok(()); // out-of-band shrunk values
            }
            let full = s1_workload(n_zf);
            // One random stream departs between rounds 1 and 2; rounds 2-3
            // then plan the survivors (at least two remain, so the CPU fill
            // stays strictly costlier than the single GPU box after the
            // price restore).
            let mut survivors = full.clone();
            survivors.remove(enc[1] as usize % survivors.len());

            // Calibrate the graph budget on the *survivor* workload — the
            // one the flip round plans: the nearest-exact candidate
            // completes exactly on it while the two-region problem, which
            // builds every graph twice against the same cumulative budget,
            // is guaranteed to wall.
            let catalog = flip_catalog(expensive);
            let budget = calibrated_budget(&catalog, &survivors);
            let mut cfg = PlannerConfig::gcl();
            cfg.solve_opts.max_graph_nodes = budget;

            let mut mgr = AdaptiveManager::new(Planner::new(catalog.clone(), cfg));
            let mut sim = CloudSim::new(catalog);

            // Round 1 — GPU-favourable prices: every candidate (exact or
            // budget-walled heuristic alike) lands on the single GPU box;
            // the tie keeps the main GCL strategy.
            let r1 = mgr.replan(full.clone()).map_err(|e| e.to_string())?;
            if r1.winner != Some(Candidate::Main) {
                return Err(format!("round 1 must keep GCL: {r1:?}"));
            }
            sim.apply_plan(mgr.current_plan().unwrap()).map_err(|e| e.to_string())?;

            // Round 2 — the departure drift.
            let r2 = mgr.replan(survivors.clone()).map_err(|e| e.to_string())?;
            if r2.winner_flipped {
                return Err(format!("drift round must not flip: {r2:?}"));
            }
            sim.apply_plan(mgr.current_plan().unwrap()).map_err(|e| e.to_string())?;
            let ids_before: Vec<_> = sim.alive().iter().map(|i| i.id).collect();

            // Round 3 — price perturbation only, workload unchanged: the
            // cheap CPU box blinds every greedy rule while the calibrated
            // budget keeps GCL's exact phase walled — the nearest-exact
            // candidate wins. Continuity must keep the fleet byte-stable.
            mgr.planner.catalog = flip_catalog(cheap);
            let r3 = mgr.replan(survivors.clone()).map_err(|e| e.to_string())?;
            if !r3.winner_flipped || r3.winner != Some(Candidate::NearestExact) {
                return Err(format!("price perturbation must flip the winner: {r3:?}"));
            }
            if (r3.cost_after - 0.65).abs() > 1e-9 {
                return Err(format!("flipped plan must keep the GPU box: {r3:?}"));
            }
            if r3.streams_moved != 0 {
                return Err(format!(
                    "identical plans across the flip moved {} streams",
                    r3.streams_moved
                ));
            }
            if r3.streams_surviving != survivors.len() {
                return Err(format!("survivor accounting broken: {r3:?}"));
            }
            if !r3.provision.is_empty() || !r3.terminate.is_empty() {
                return Err(format!("flip changed the fleet: {r3:?}"));
            }
            sim.apply_plan(mgr.current_plan().unwrap()).map_err(|e| e.to_string())?;
            let ids_after: Vec<_> = sim.alive().iter().map(|i| i.id).collect();
            if ids_before != ids_after {
                return Err(format!(
                    "identical plans must keep identical instance ids: \
                     {ids_before:?} vs {ids_after:?}"
                ));
            }
            Ok(())
        },
    );
}

/// The unified portfolio runtime never changes what is planned, only how
/// fast and how stably: warm portfolio re-plans (shared worker pool,
/// cross-candidate budget pool, winner-assignment seeding, accumulated
/// telemetry) must cost exactly what a cold plan through fresh contexts —
/// the three-independent-contexts baseline — costs wherever both exact
/// phases complete, and never more anywhere (extra budget and warm seeds
/// can only improve a heuristic fallback).
#[test]
fn prop_portfolio_runtime_preserves_plan_costs() {
    use camflow::cameras::scenarios;
    use camflow::coordinator::adaptive::AdaptiveManager;
    use camflow::coordinator::Plan;
    let catalog = Catalog::builtin();
    let exact_complete = |p: &Plan| {
        p.pipeline.components_fallback == 0
            && p.pipeline.components_proven == p.pipeline.components
    };
    check(
        0x5EED5,
        5,
        |rng: &mut Rng| vec![rng.next_u64()],
        |seed: &Vec<u64>| {
            let Some(&s) = seed.first() else { return Ok(()) };
            let mut rng = Rng::new(s);
            let planner = Planner::new(catalog.clone(), PlannerConfig::gcl());
            let mut mgr = AdaptiveManager::new(planner.clone());
            for step in 0..3u64 {
                let n = 8 + rng.index(8);
                let fps = rng.range_f64(1.0, 6.0);
                let requests = scenarios::fig6_workload(n, fps, s ^ step);
                let warm = mgr.replan(requests.clone()).map_err(|e| e.to_string())?;
                let cold = planner.plan(&requests).map_err(|e| e.to_string())?;
                if warm.cost_after > cold.cost_per_hour + 1e-9 {
                    return Err(format!(
                        "step {step}: portfolio runtime cost {} worse than the \
                         independent-context baseline {}",
                        warm.cost_after, cold.cost_per_hour
                    ));
                }
                let warm_plan = mgr.current_plan().unwrap();
                if exact_complete(warm_plan)
                    && exact_complete(&cold)
                    && (warm.cost_after - cold.cost_per_hour).abs() > 1e-9
                {
                    return Err(format!(
                        "step {step}: exact-complete portfolio cost {} diverged from \
                         the baseline {}",
                        warm.cost_after, cold.cost_per_hour
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The dirty-tracking front-end is bit-identical to a cold full rebuild.
/// Random churn (add / remove / move / fps-change) over a seeded fleet,
/// re-planned through one warm context: after every churn step the warm
/// context's `GroupSet` and `PackingProblem` must equal a fresh context's
/// full rebuild exactly, and the plan cost must match the cold plan
/// wherever both exact phases completed (the warm seed can only improve a
/// budget-bound fallback, never an exact solve).
#[test]
fn prop_incremental_front_end_matches_cold_rebuild() {
    use camflow::cameras::CameraDb;
    use camflow::coordinator::pipeline::{
        front_end_with_context, plan_with_context, PlanContext,
    };
    let catalog = Catalog::builtin();
    let cfg = PlannerConfig::gcl();
    let exact_complete = |p: &camflow::coordinator::Plan| {
        p.pipeline.components_fallback == 0
            && p.pipeline.components_proven == p.pipeline.components
    };
    check(
        0xD21F7,
        8,
        |rng: &mut Rng| vec![rng.next_u64()],
        |seed: &Vec<u64>| {
            let mut rng = Rng::new(seed[0]);
            let db = CameraDb::synthetic(24, seed[0] ^ 0xA5);
            let mut requests = db.workload(Program::Zf, 4.0);
            let mut warm = PlanContext::new();
            front_end_with_context(&catalog, &cfg, &requests, &mut warm)
                .map_err(|e| e.to_string())?;
            for step in 0..4 {
                // 1-3 churn ops per step.
                for op in 0..1 + rng.index(3) {
                    match rng.index(4) {
                        0 => {
                            let (city, at) = *rng.choose(camflow::geo::cities::ALL);
                            requests.push(StreamRequest::new(
                                camera_at(
                                    1000 + step as u64 * 10 + op as u64,
                                    city,
                                    at,
                                    Resolution::VGA,
                                    30.0,
                                ),
                                Program::Zf,
                                rng.range_f64(0.5, 8.0),
                            ));
                        }
                        1 => {
                            if requests.len() > 1 {
                                let i = rng.index(requests.len());
                                requests.remove(i);
                            }
                        }
                        2 => {
                            let i = rng.index(requests.len());
                            let loc = requests[i].camera.location;
                            requests[i].camera.location = GeoPoint::new(
                                (loc.lat + rng.normal() * 2.0).clamp(-60.0, 65.0),
                                loc.lon + rng.normal() * 2.0,
                            );
                        }
                        _ => {
                            let i = rng.index(requests.len());
                            requests[i].desired_fps = rng.range_f64(0.5, 8.0);
                        }
                    }
                }
                let (wg, wp) = front_end_with_context(&catalog, &cfg, &requests, &mut warm)
                    .map_err(|e| e.to_string())?;
                let (cg, cp) =
                    front_end_with_context(&catalog, &cfg, &requests, &mut PlanContext::new())
                        .map_err(|e| e.to_string())?;
                if wg != cg {
                    return Err(format!(
                        "step {step}: incremental GroupSet diverged: {wg:?} vs {cg:?}"
                    ));
                }
                if wp != cp {
                    return Err(format!("step {step}: incremental problem diverged"));
                }
                let warm_plan = plan_with_context(&catalog, &cfg, &requests, &mut warm);
                let cold_plan =
                    plan_with_context(&catalog, &cfg, &requests, &mut PlanContext::new());
                match (warm_plan, cold_plan) {
                    (Ok(w), Ok(c)) => {
                        if w.cost_per_hour > c.cost_per_hour + 1e-9 {
                            return Err(format!(
                                "step {step}: warm plan {} worse than cold {}",
                                w.cost_per_hour, c.cost_per_hour
                            ));
                        }
                        if exact_complete(&w)
                            && exact_complete(&c)
                            && (w.cost_per_hour - c.cost_per_hour).abs() > 1e-9
                        {
                            return Err(format!(
                                "step {step}: warm exact cost {} != cold exact cost {}",
                                w.cost_per_hour, c.cost_per_hour
                            ));
                        }
                    }
                    // An infeasible churned workload must fail both ways.
                    (Err(_), Err(_)) => {}
                    (Ok(_), Err(e)) => {
                        return Err(format!("step {step}: cold failed where warm planned: {e}"));
                    }
                    (Err(e), Ok(_)) => {
                        return Err(format!("step {step}: warm failed where cold planned: {e}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Geo invariants: symmetry, triangle-ish behavior of RTT, circle monotone.
#[test]
fn prop_geo_invariants() {
    check(
        0x6E0,
        100,
        |rng: &mut Rng| {
            vec![
                (rng.range_f64(-60.0, 65.0) * 1000.0).round(),
                (rng.range_f64(-180.0, 180.0) * 1000.0).round(),
                (rng.range_f64(-60.0, 65.0) * 1000.0).round(),
                (rng.range_f64(-180.0, 180.0) * 1000.0).round(),
                (rng.range_f64(0.3, 30.0) * 1000.0).round(),
            ]
        },
        |v| {
            let a = GeoPoint::new(v[0] / 1000.0, v[1] / 1000.0);
            let b = GeoPoint::new(v[2] / 1000.0, v[3] / 1000.0);
            let fps = v[4] / 1000.0;
            let d1 = a.distance_km(&b);
            let d2 = b.distance_km(&a);
            if (d1 - d2).abs() > 1e-6 {
                return Err("distance asymmetric".into());
            }
            if !(0.0..=20040.0).contains(&d1) {
                return Err(format!("distance out of range: {d1}"));
            }
            if a.rtt_ms(&b) < geo::RTT_BASE_MS {
                return Err("rtt below base".into());
            }
            // Reachability is monotone in fps: reachable at high fps implies
            // reachable at any lower fps.
            if geo::reachable(&a, &b, fps) && !geo::reachable(&a, &b, fps / 2.0) {
                return Err("reachability not monotone".into());
            }
            Ok(())
        },
    );
}

/// JSON round-trip for machine-generated values.
#[test]
fn prop_json_roundtrip() {
    fn gen_value(rng: &mut Rng, depth: usize) -> json::Value {
        match if depth == 0 { rng.index(4) } else { rng.index(6) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.bool(0.5)),
            2 => json::Value::Num((rng.range_f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => json::Value::Str(format!("s{}-é✓", rng.next_u64() % 1000)),
            4 => json::Value::Arr((0..rng.index(4)).map(|_| gen_value(rng, depth - 1)).collect()),
            _ => json::Value::obj(
                (0..rng.index(4))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                    .map(|(k, v)| (Box::leak(k.into_boxed_str()) as &str, v))
                    .collect(),
            ),
        }
    }
    check(
        0x15,
        100,
        |rng: &mut Rng| vec![rng.next_u64()],
        |seed| {
            let mut rng = Rng::new(seed[0]);
            let v = gen_value(&mut rng, 3);
            let s = json::to_string_pretty(&v);
            let back = json::parse(&s).map_err(|e| e.to_string())?;
            if back != v {
                return Err(format!("roundtrip mismatch: {s}"));
            }
            Ok(())
        },
    );
}

/// Dims arithmetic is componentwise and headroom scaling is linear.
#[test]
fn prop_dims_arithmetic() {
    check(
        7,
        100,
        |rng: &mut Rng| {
            (0..8)
                .map(|_| (rng.range_f64(0.0, 50.0) * 10.0).round() / 10.0)
                .collect::<Vec<f64>>()
        },
        |v| {
            let a = Dims::new(v[0], v[1], v[2], v[3]);
            let b = Dims::new(v[4], v[5], v[6], v[7]);
            let sum = a.add(&b);
            for ((x, y), s) in a
                .as_array()
                .iter()
                .zip(b.as_array())
                .zip(sum.as_array())
            {
                if (x + y - s).abs() > 1e-12 {
                    return Err("add not componentwise".into());
                }
            }
            if !a.fits_in(&sum) || !b.fits_in(&sum) {
                return Err("a must fit in a+b".into());
            }
            let scaled = a.scale(0.9);
            if !scaled.fits_in(&a) && !a.is_zero() {
                return Err("0.9-scaled must fit".into());
            }
            Ok(())
        },
    );
}

/// On region-disjoint workloads — every metro's coverage circle stays inside
/// its own region cluster at fps >= 32 — the metro-sharded planner produces
/// one shard per populated basin and its total cost equals the unsharded
/// single-context plan exactly whenever both sides certify (every component
/// exact-complete, the Main candidate winning in every shard).
#[test]
fn prop_sharded_plan_cost_equals_unsharded_on_disjoint_metros() {
    let catalog = Catalog::builtin().restrict(
        Some(&["c4.2xlarge", "c4.8xlarge", "g2.2xlarge", "g3.8xlarge"]),
        Some(&[
            "us-east-1",
            "us-east-2",
            "us-west-1",
            "us-west-2",
            "eu-west-1",
            "eu-west-2",
            "eu-central-1",
            "ap-southeast-1",
            "ap-southeast-2",
            "ap-northeast-1",
            "ap-south-1",
            "sa-east-1",
        ]),
    );
    // The eight basin anchors are EC2 region cities.
    let basins: [(f64, f64); 8] = [
        (38.95, -77.45),
        (45.84, -119.70),
        (53.34, -6.27),
        (1.35, 103.82),
        (-33.87, 151.21),
        (35.68, 139.69),
        (19.08, 72.88),
        (-23.55, -46.63),
    ];
    check(
        0x5AD5,
        12,
        |rng: &mut Rng| {
            // Flat encoding: triples of (basin, fps tier, resolution pick).
            let n = 2 + rng.index(7);
            let mut v = Vec::with_capacity(n * 3);
            for _ in 0..n {
                v.push(rng.index(8) as u64);
                v.push(rng.index(3) as u64);
                v.push(rng.index(2) as u64);
            }
            v
        },
        |spec: &Vec<u64>| {
            let requests: Vec<StreamRequest> = spec
                .chunks_exact(3)
                .enumerate()
                .map(|(i, c)| {
                    let (lat, lon) = basins[(c[0] as usize) % 8];
                    let at = GeoPoint::new(lat + i as f64 * 1e-7, lon + i as f64 * 1e-7);
                    let res = if c[2] % 2 == 0 { Resolution::VGA } else { Resolution::XGA };
                    StreamRequest::new(
                        camera_at(i as u64, "metro", at, res, 30.0),
                        Program::Zf,
                        [32.0, 36.0, 40.0][(c[1] as usize) % 3],
                    )
                })
                .collect();
            if requests.is_empty() {
                return Ok(());
            }
            let distinct_basins: std::collections::BTreeSet<u64> =
                spec.chunks_exact(3).map(|c| c[0] % 8).collect();
            let mut sp =
                ShardedPlanner::new(Planner::new(catalog.clone(), PlannerConfig::gcl()));
            let sharded = sp.replan(&requests);
            let reference =
                Planner::new(catalog.clone(), PlannerConfig::gcl()).plan_single(&requests);
            match (sharded, reference) {
                // Feasibility must agree between the two architectures.
                (Err(_), Err(_)) => Ok(()),
                (Ok(_), Err(e)) => Err(format!("unsharded failed, sharded succeeded: {e}")),
                (Err(e), Ok(_)) => Err(format!("sharded failed, unsharded succeeded: {e}")),
                (Ok(s), Ok(r)) => {
                    if s.total_shards != distinct_basins.len() {
                        return Err(format!(
                            "{} shards for {} distinct basins",
                            s.total_shards,
                            distinct_basins.len()
                        ));
                    }
                    let ref_exact = r.pipeline.components_fallback == 0
                        && r.pipeline.components_proven == r.pipeline.components;
                    if s.exact_complete() && s.all_main() && ref_exact {
                        let diff = (s.cost_per_hour - r.cost_per_hour).abs();
                        if diff >= 1e-6 {
                            return Err(format!(
                                "sharded {} != unsharded {}",
                                s.cost_per_hour, r.cost_per_hour
                            ));
                        }
                    }
                    Ok(())
                }
            }
        },
    );
}

/// Expand's slot<->bin matching keeps the *maximum* possible number of
/// streams in place: the kept-stream count of `expand::run` equals a
/// brute-force optimal assignment of previous slots to new bins. (The greedy
/// primary is certified by an exact Hungarian pass whenever its total falls
/// short of the matching upper bound.)
#[test]
fn prop_expand_matching_keeps_the_optimal_stream_count() {
    check(
        0xE8A4D,
        120,
        |rng: &mut Rng| (0..20).map(|_| rng.next_u64()).collect::<Vec<u64>>(),
        |v: &Vec<u64>| {
            let pick = |i: usize| v.get(i).copied().unwrap_or(0);
            let nb = 1 + (pick(0) % 3) as usize;
            let ns = 1 + (pick(1) % 3) as usize;
            // counts[bi][g] for two item groups; drop empty bins (the solver
            // never emits one).
            let counts: Vec<Vec<usize>> = (0..nb)
                .map(|bi| (0..2).map(|g| (pick(2 + bi * 2 + g) % 3) as usize).collect())
                .filter(|c: &Vec<usize>| c.iter().sum::<usize>() > 0)
                .collect();
            if counts.is_empty() {
                return Ok(());
            }
            let cnt: [usize; 2] = [
                counts.iter().map(|c| c[0]).sum(),
                counts.iter().map(|c| c[1]).sum(),
            ];
            let total = cnt[0] + cnt[1];
            let problem = PackingProblem::new(
                (0..2)
                    .map(|g| ItemGroup {
                        label: format!("g{g}"),
                        count: cnt[g],
                        demand_per_bin: vec![Some(Dims::new(1.0, 1.0, 0.0, 0.0))],
                    })
                    .collect(),
                vec![BinType {
                    label: "cpu@r".into(),
                    capacity: Dims::new(50.0, 50.0, 0.0, 0.0),
                    cost: 1.0,
                    type_idx: 0,
                    region_idx: 0,
                    has_gpu: false,
                }],
            );
            let packing = Packing {
                bins: counts
                    .iter()
                    .map(|c| PackedBin { bin_type: 0, counts: c.clone() })
                    .collect(),
            };
            let members = vec![(0..cnt[0]).collect::<Vec<_>>(), (cnt[0]..total).collect()];
            let keys: Vec<StreamKey> = (0..total)
                .map(|i| StreamKey {
                    camera_id: i as u64,
                    program: "ZF",
                    fps_bits: 1.0f64.to_bits(),
                    occurrence: 0,
                })
                .collect();
            // Each stream is hosted by one previous slot or none.
            let owner: Vec<Option<usize>> = (0..total)
                .map(|s| {
                    let o = (pick(8 + s) % (ns as u64 + 1)) as usize;
                    (o < ns).then_some(o)
                })
                .collect();
            let prev = PrevAssignment {
                slots: (0..ns)
                    .map(|si| PrevSlot {
                        slot_id: 100 + si as u64,
                        label: "cpu@r".into(),
                        streams: (0..total)
                            .filter(|&s| owner[s] == Some(si))
                            .map(|s| keys[s])
                            .collect(),
                    })
                    .collect(),
            };

            let instances = expand::run(&problem, &packing, &members, &keys, Some(&prev))
                .map_err(|e| e.to_string())?;
            let mut measured = 0usize;
            for inst in &instances {
                let sid = inst.slot_id;
                if (100..100 + ns as u64).contains(&sid) {
                    let si = (sid - 100) as usize;
                    measured +=
                        inst.streams.iter().filter(|&&s| owner[s] == Some(si)).count();
                }
            }

            // Brute force: overlap of slot si with bin bi is the per-group
            // min of hosted and packed counts; maximize over injective
            // slot -> bin assignments.
            let group_of = |s: usize| usize::from(s >= cnt[0]);
            let mut surv = vec![[0usize; 2]; ns];
            for s in 0..total {
                if let Some(si) = owner[s] {
                    surv[si][group_of(s)] += 1;
                }
            }
            let ov: Vec<Vec<usize>> = (0..ns)
                .map(|si| {
                    counts
                        .iter()
                        .map(|c| surv[si][0].min(c[0]) + surv[si][1].min(c[1]))
                        .collect()
                })
                .collect();
            fn best(si: usize, ov: &[Vec<usize>], used: &mut [bool]) -> usize {
                if si == ov.len() {
                    return 0;
                }
                // The slot may also stay unmatched.
                let mut top = best(si + 1, ov, used);
                for bi in 0..used.len() {
                    if !used[bi] {
                        used[bi] = true;
                        top = top.max(ov[si][bi] + best(si + 1, ov, used));
                        used[bi] = false;
                    }
                }
                top
            }
            let optimal = best(0, &ov, &mut vec![false; counts.len()]);
            if measured != optimal {
                return Err(format!(
                    "expand kept {measured} streams, optimal matching keeps {optimal} \
                     (ov={ov:?})"
                ));
            }
            Ok(())
        },
    );
}

/// Explicitly-defaulted feedback through an empty controller is
/// indistinguishable from no feedback at all: the warm re-plan sees a
/// bit-identical workload, reports a zero feedback delta, and produces a
/// bit-identical plan with an untouched fleet.
#[test]
fn prop_zero_feedback_delta_is_plan_noop() {
    use camflow::cameras::DemandFeedback;
    use camflow::coordinator::adaptive::AdaptiveManager;
    use camflow::server::feedback::{FeedbackConfig, FeedbackController};
    let catalog =
        Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
    check(
        0xFEEDBAC,
        15,
        |rng: &mut Rng| {
            // Flat encoding: pairs of (is_vgg, fps*100).
            let n = 1 + rng.index(5);
            let mut v = Vec::with_capacity(n * 2);
            for _ in 0..n {
                v.push(rng.index(2) as u64);
                v.push((rng.range_f64(0.2, 1.5) * 100.0).round() as u64);
            }
            v
        },
        |spec: &Vec<u64>| {
            let requests: Vec<StreamRequest> = spec
                .chunks_exact(2)
                .filter(|c| c[1] > 0)
                .enumerate()
                .map(|(i, c)| {
                    StreamRequest::new(
                        camera_at(i as u64, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0),
                        if c[0] == 1 { Program::Vgg16 } else { Program::Zf },
                        c[1] as f64 / 100.0,
                    )
                })
                .collect();
            if requests.is_empty() {
                return Ok(());
            }
            let mut mgr = AdaptiveManager::new(Planner::new(catalog.clone(), PlannerConfig::st3()));
            let Ok(first) = mgr.replan(requests.clone()) else {
                return Ok(()); // infeasible workloads are not the property's concern
            };
            // Re-plan the same workload with every feedback field written
            // explicitly to its default, through a controller that has
            // observed nothing.
            let mut defaulted = requests;
            for r in &mut defaulted {
                r.feedback = DemandFeedback::default();
            }
            let fc = FeedbackController::new(FeedbackConfig::default());
            let (report, changed) =
                mgr.replan_with_feedback(defaulted, &fc).map_err(|e| e.to_string())?;
            if changed != 0 {
                return Err(format!("empty controller changed {changed} requests"));
            }
            if report.cost_after.to_bits() != first.cost_after.to_bits() {
                return Err(format!(
                    "zero-delta re-plan changed cost: {} -> {}",
                    first.cost_after, report.cost_after
                ));
            }
            if report.streams_moved != 0
                || !report.provision.is_empty()
                || !report.terminate.is_empty()
            {
                return Err(format!("zero-delta re-plan touched the fleet: {report:?}"));
            }
            Ok(())
        },
    );
}

/// Under any observation sequence the degrade controller never exceeds its
/// configured deepest tier, never publishes a cost scale outside the clamp,
/// and never sheds a stream to zero (or above its declared) fps.
#[test]
fn prop_degrade_tiers_never_silence_streams() {
    use camflow::metrics::MetricsWindow;
    use camflow::server::feedback::{FeedbackConfig, FeedbackController};
    use camflow::server::sim::{InstanceWindow, StreamWindow};
    check(
        0xDE64ADE,
        40,
        |rng: &mut Rng| {
            // Flat encoding per window: (queue depth, dropped, util%,
            // analyzed, measured cost x100).
            let wins = 1 + rng.index(12);
            let mut v = Vec::with_capacity(wins * 5);
            for _ in 0..wins {
                v.push(rng.index(65) as u64);
                v.push(rng.index(4) as u64);
                v.push(rng.index(130) as u64);
                v.push(1 + rng.index(20) as u64);
                v.push((rng.range_f64(0.01, 30.0) * 100.0).round() as u64);
            }
            v
        },
        |spec: &Vec<u64>| {
            let cfg = FeedbackConfig::default();
            let mut fc = FeedbackController::new(cfg.clone());
            let mut req = StreamRequest::new(
                camera_at(0, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0),
                Program::Zf,
                0.2,
            );
            for c in spec.chunks_exact(5) {
                let (depth, dropped, util, analyzed) = (c[0], c[1], c[2], c[3]);
                let stream = StreamWindow {
                    stream_idx: 0,
                    frames_emitted: analyzed + dropped,
                    frames_analyzed: analyzed,
                    frames_dropped: dropped,
                    measured_cost_s: c[4] as f64 / 100.0,
                    declared_cost_s: analyzed as f64 * 0.5,
                };
                fc.observe(&[InstanceWindow {
                    slot_id: 7,
                    window: MetricsWindow {
                        frames_in: analyzed + dropped,
                        frames_analyzed: analyzed,
                        frames_dropped: dropped,
                        batches: 1,
                        queue_depth: depth as f64,
                    },
                    queue_capacity: 64,
                    utilization: util as f64 / 100.0,
                    streams: vec![stream],
                }]);
                let fb = fc.feedback_for(0);
                if fb.shed_tier > cfg.max_tier {
                    return Err(format!("tier {} above max {}", fb.shed_tier, cfg.max_tier));
                }
                if !(cfg.scale_min..=cfg.scale_max).contains(&fb.cost_scale) {
                    return Err(format!("published scale {} escaped the clamp", fb.cost_scale));
                }
                req.feedback = fb;
                if req.effective_fps() <= 0.0 {
                    return Err(format!("stream shed to zero fps: {fb:?}"));
                }
                if req.effective_fps() > req.desired_fps {
                    return Err("shed raised the frame rate".into());
                }
            }
            Ok(())
        },
    );
}

/// A preemption absorbed as a structural delta on the temporal axis moves
/// only the preempted jobs: no surviving placement sits on a revoked lane at
/// or after the cut hour, every untouched item keeps its placements
/// bit-identically, every moved id really was stranded, fresh sheds come
/// only from moved items, and the repaired bill re-prices exactly the
/// occupied paid lane-hours.
#[test]
fn prop_preemption_absorb_moves_only_preempted_jobs() {
    check(
        0x5B07_0001,
        80,
        |rng: &mut Rng| rng.next_u64(),
        |&seed: &u64| {
            let mut rng = Rng::new(seed);
            let horizon = 10 + rng.index(6);
            // One free slack lane plus 1-4 paid lanes, mostly spot.
            let mut lanes = vec![TemporalLane {
                label: "slack".to_string(),
                kind: LaneKind::LiveSlack,
                usable: Dims::new(rng.range_f64(1.0, 6.0), rng.range_f64(2.0, 8.0), 0.0, 0.0),
                hourly_cost: 0.0,
                from_hour: 0,
            }];
            for l in 0..1 + rng.index(4) {
                let spot = rng.bool(0.6);
                lanes.push(TemporalLane {
                    label: format!("paid{l}"),
                    kind: if spot { LaneKind::Spot } else { LaneKind::OnDemand },
                    usable: Dims::new(
                        rng.range_f64(2.0, 12.0),
                        rng.range_f64(4.0, 24.0),
                        0.0,
                        0.0,
                    ),
                    hourly_cost: rng.range_f64(0.05, 1.5),
                    from_hour: 0,
                });
            }
            let items: Vec<BackfillItem> = (0..3 + rng.index(8) as u64)
                .map(|id| BackfillItem {
                    id,
                    demand: Dims::new(rng.range_f64(0.3, 3.0), rng.range_f64(0.3, 3.0), 0.0, 0.0),
                    units: 1 + rng.index(5),
                    deadline_hour: 2 + rng.index(horizon),
                    preemptible: rng.bool(0.7),
                })
                .collect();
            let schedule = pack_backfill(&lanes, &items, horizon);

            // Revoke 1-2 paid lanes at a random cut hour.
            let mut revoked: Vec<usize> = Vec::new();
            for _ in 0..1 + rng.index(2) {
                let l = 1 + rng.index(lanes.len() - 1);
                if !revoked.contains(&l) {
                    revoked.push(l);
                }
            }
            let hour = rng.index(horizon);
            let (repaired, moved) =
                rehome_backfill(&lanes, &items, &schedule, &revoked, hour, horizon);

            for p in &repaired.placements {
                if p.hour >= hour && revoked.contains(&p.lane) {
                    return Err(format!("{p:?} survived on a revoked lane"));
                }
            }
            let stranded: BTreeSet<u64> = schedule
                .placements
                .iter()
                .filter(|p| p.hour >= hour && revoked.contains(&p.lane))
                .map(|p| p.item)
                .collect();
            for id in &moved {
                if !stranded.contains(id) {
                    return Err(format!("item {id} moved without being stranded"));
                }
            }
            for item in &items {
                if moved.contains(&item.id) {
                    continue;
                }
                let before: Vec<_> =
                    schedule.placements.iter().filter(|p| p.item == item.id).collect();
                let after: Vec<_> =
                    repaired.placements.iter().filter(|p| p.item == item.id).collect();
                if before != after {
                    return Err(format!("untouched item {} was rearranged", item.id));
                }
            }
            for id in &repaired.shed {
                if !schedule.shed.contains(id) && !moved.contains(id) {
                    return Err(format!("item {id} shed without being preempted"));
                }
            }
            if moved.is_empty() && repaired.placements != schedule.placements {
                return Err("no-op absorb changed the schedule".to_string());
            }
            let mut cells: Vec<(usize, usize)> =
                repaired.placements.iter().map(|p| (p.lane, p.hour)).collect();
            cells.sort_unstable();
            cells.dedup();
            let bill: f64 = cells.iter().map(|&(l, _)| lanes[l].hourly_cost).sum();
            if (bill - repaired.cost).abs() > 1e-9 {
                return Err(format!("cost {} != rebill {bill}", repaired.cost));
            }
            Ok(())
        },
    );
}

/// On identical live streams and backfill queries, the certified gate makes
/// the spot-enabled planner's backfill schedule never costlier — and never
/// more shedding — than the on-demand-only planner's; the live fleets are
/// identical (live never rides revocable capacity), the on-demand-only plan
/// offers no spot lanes at all, and non-preemptible items never land on one.
#[test]
fn prop_spot_plan_never_costlier_than_on_demand_only() {
    let catalog =
        Catalog::builtin().restrict(Some(&["c4.2xlarge", "c4.8xlarge"]), Some(&["us-east-2"]));
    check(
        0x5B07_0002,
        25,
        |rng: &mut Rng| rng.next_u64(),
        |&seed: &u64| {
            let mut rng = Rng::new(seed);
            let queries = scenarios::diurnal_backfill(4 + rng.index(21), rng.next_u64());
            let items = SpotPlanner::items_from_queries(&queries);
            let requests: Vec<StreamRequest> = (0..1 + rng.index(3) as u64)
                .map(|i| {
                    StreamRequest::new(
                        camera_at(i, "Chicago", cities::CHICAGO, Resolution::XGA, 30.0),
                        Program::Zf,
                        0.5,
                    )
                })
                .collect();
            let now_hour = rng.index(4);

            let spot_cfg =
                SpotPlannerConfig { horizon_hours: 48, use_spot: true, lanes_per_offering: 2 };
            let od_cfg = SpotPlannerConfig { use_spot: false, ..spot_cfg };
            let mut sp = SpotPlanner::new(catalog.clone(), PlannerConfig::st1(), spot_cfg);
            let mut od = SpotPlanner::new(catalog.clone(), PlannerConfig::st1(), od_cfg);
            let sp_plan = sp.plan(&requests, &items, now_hour).map_err(|e| e.to_string())?;
            let od_plan = od.plan(&requests, &items, now_hour).map_err(|e| e.to_string())?;

            if sp_plan.backfill_cost > od_plan.backfill_cost + 1e-9 {
                return Err(format!(
                    "spot backfill {} costlier than on-demand-only {}",
                    sp_plan.backfill_cost, od_plan.backfill_cost
                ));
            }
            if sp_plan.backfill_cost > sp_plan.baseline_cost + 1e-9 {
                return Err("adopted schedule costlier than its own baseline".to_string());
            }
            if sp_plan.schedule.shed.len() > od_plan.schedule.shed.len() {
                return Err(format!(
                    "spot plan sheds {} items, on-demand-only {}",
                    sp_plan.schedule.shed.len(),
                    od_plan.schedule.shed.len()
                ));
            }
            if (sp_plan.live.cost_per_hour - od_plan.live.cost_per_hour).abs() > 1e-9 {
                return Err("live fleet cost diverged between configurations".to_string());
            }
            if od_plan.lanes.iter().any(|l| l.kind == LaneKind::Spot) {
                return Err("on-demand-only plan offered a spot lane".to_string());
            }
            for p in &sp_plan.schedule.placements {
                if sp_plan.lanes[p.lane].kind != LaneKind::Spot {
                    continue;
                }
                let item = items.iter().find(|it| it.id == p.item).expect("placed item exists");
                if !item.preemptible {
                    return Err(format!("non-preemptible item {} on a spot lane", p.item));
                }
            }
            Ok(())
        },
    );
}
