//! Closed-loop serving→planning feedback bars (scenarios in
//! `camflow::bench::closedloop`):
//!
//! * **over-declared fleet** — true frame cost 0.5× the declared profile:
//!   the converged closed-loop plan must cost no more than (here: strictly
//!   less than) the declared-demand plan, with zero drops/sheds and higher
//!   fleet utilization,
//! * **under-declared fleet** — true frame cost 2× declared: degrade tiers
//!   shed fps before wholesale drops, the corrected re-plan provisions real
//!   capacity, tiers restore under sustained headroom, and the final drop
//!   rate stays bounded while the open-loop control keeps dropping.
//!
//! All bars are deterministic (the serving simulator has no threads, RNG,
//! or wall clock) and asserted inside the library scenarios, so this binary
//! and `tests/integration.rs` gate on exactly the same invariants. The only
//! wall-clock number is the recorded epoch timing, which is never asserted.
//!
//! Emits `BENCH_closedloop.json` so the feedback trajectory is tracked
//! across PRs.

use camflow::bench::{Bench, Table};
use camflow::util::json::Value;

fn main() {
    println!("== Closed-loop serving feedback: over/under-declared fleets ==");
    let bench = Bench::new(1, 3);
    let timing = bench.run("closed-loop scenarios", || {
        let _ = camflow::bench::closedloop::run();
    });
    let o = camflow::bench::closedloop::run();

    let mut t = Table::new(&["scenario", "declared $/h", "closed $/h", "drop rate", "extra"]);
    t.row(&[
        "over-declared (0.5x)".to_string(),
        format!("{:.3}", o.over.declared_usd_per_hour),
        format!("{:.3}", o.over.closedloop_usd_per_hour),
        format!("{:.4}", o.over.final_drop_rate),
        format!(
            "util {:.2} -> {:.2}",
            o.over.fleet_util_declared, o.over.fleet_util_closed
        ),
    ]);
    t.row(&[
        "under-declared (2x)".to_string(),
        format!("{:.3}", o.under.declared_usd_per_hour),
        format!("{:.3}", o.under.corrected_usd_per_hour),
        format!(
            "{:.4} (open-loop {:.4})",
            o.under.final_drop_rate, o.under.nofeedback_drop_rate
        ),
        format!(
            "max tier {}, shed peak {}",
            o.under.max_shed_tier, o.under.peak_streams_shed
        ),
    ]);
    t.print();
    println!(
        "feedback_streams {}  degraded_tier_streams {}  ({:.0} ms per full loop)",
        o.over.feedback_streams, o.under.degraded_tier_streams, timing.mean_ms
    );

    let doc = Value::obj(vec![
        ("bench", Value::str("closedloop")),
        ("closedloop", o.to_json()),
        ("loop_ms", Value::num(timing.mean_ms)),
    ]);
    let path = "BENCH_closedloop.json";
    std::fs::write(path, camflow::util::json::to_string_pretty(&doc))
        .expect("write BENCH_closedloop.json");
    println!("\nwrote {path}");
    println!("\nbench_closedloop OK");
}
