//! 10k+-stream scale benchmarks: adaptive solver budgets + delta-solve
//! reuse (the "thousands of cameras per metro" regime of Jain et al.,
//! "Scaling Video Analytics Systems to Large Camera Deployments").
//!
//! Three sections, written to `BENCH_scale.json` (fields documented in the
//! crate docs, `lib.rs`):
//!
//! * **parity** — cold plan vs warm re-plan of a ≈1%-perturbed 10k-stream
//!   workload. Deterministic bars: the warm re-plan's cost equals the cold
//!   exact cost on every scenario where the cold exact phase completed
//!   (proved optimality in every component), and the delta-solve path must
//!   actually fire. Wall-clock speedup is recorded, and gated only without
//!   `BENCH_LENIENT_TIMING` (shared CI runners are noisy).
//! * **exact_recovery** — a probe run measures each component's true
//!   arc-flow need, then a static budget is pinned *between* the hardest
//!   and second-hardest component. Under that static budget the hard metro
//!   must heuristic-fall-back (the seed behaviour at scale); under
//!   adaptive budgets the donated pool must carry it back to an exact
//!   solve. Fully deterministic — the budgets are calibrated from measured
//!   needs, not guessed constants.
//! * **lp_reuse** — warm vs cold node-LP counts over the parity runs (the
//!   dual-simplex resume at work).
//!
//! The parity section also records a **per-stage latency breakdown**
//! (eligibility / build / solve / expand) for the cold and warm runs and
//! gates the drift-proportional front-end: the ≈1%-drift warm re-plan's
//! front-end (Eligibility + ProblemBuild) must run ≥ 5× faster than the
//! cold full rebuild's, and its dirty-tracking split (`front_unchanged` /
//! `front_changed`) must equal the constructed drift exactly. The split
//! assertions are deterministic; the 5× timing bar holds with a wide
//! margin (the warm front-end does per-request map lookups where the cold
//! one recomputes coverage circles) and is asserted unconditionally.

use camflow::cameras::{camera_at, StreamRequest};
use camflow::catalog::Catalog;
use camflow::coordinator::pipeline::{plan_with_context, PlanContext};
use camflow::coordinator::{Plan, PlannerConfig};
use camflow::geo::GeoPoint;
use camflow::packing::mcvbp::SolveOptions;
use camflow::profiles::{Program, Resolution};
use camflow::solver::MilpOptions;
use camflow::util::json::Value;
use std::time::Instant;

/// Metro spec: name, location (a region city, so nothing degrades), camera
/// count per tier, tiers as (fps, resolution).
struct Metro {
    name: &'static str,
    at: GeoPoint,
    per_tier: usize,
    tiers: Vec<(f64, Resolution)>,
}

/// The eight easy metros center exactly on EC2 region cities (cameras
/// jitter within ~10 m of the center — see `requests_for`), far enough
/// apart that their RTT circles at ≥20 fps stay in separate region
/// clusters.
fn easy_metros(per_tier: usize, fps: f64) -> Vec<Metro> {
    let cities: [(&'static str, GeoPoint); 8] = [
        ("Ohio", GeoPoint::new(39.96, -82.99)),
        ("Oregon", GeoPoint::new(45.84, -119.70)),
        ("Ireland", GeoPoint::new(53.34, -6.27)),
        ("Frankfurt", GeoPoint::new(50.11, 8.68)),
        ("Singapore", GeoPoint::new(1.35, 103.82)),
        ("Sydney", GeoPoint::new(-33.87, 151.21)),
        ("Mumbai", GeoPoint::new(19.08, 72.88)),
        ("SaoPaulo", GeoPoint::new(-23.55, -46.63)),
    ];
    cities
        .into_iter()
        .map(|(name, at)| Metro {
            name,
            at,
            per_tier,
            tiers: vec![(fps, Resolution::VGA)],
        })
        .collect()
}

fn requests_for(metros: &[Metro]) -> Vec<StreamRequest> {
    let mut out = Vec::new();
    let mut id = 0u64;
    for m in metros {
        for &(fps, res) in &m.tiers {
            for _ in 0..m.per_tier {
                // Spread cameras within ~10 m of the metro center: every
                // camera gets a *distinct* position (distinct eligibility
                // memo entries, like a real fleet — the cold front-end must
                // pay per-camera coverage circles) while staying far inside
                // or outside the same RTT circles, so the per-metro
                // grouping and everything solver-side is unchanged.
                let at = GeoPoint::new(
                    m.at.lat + (id % 997) as f64 * 1e-7,
                    m.at.lon + (id % 1009) as f64 * 1e-7,
                );
                out.push(StreamRequest::new(
                    camera_at(id, m.name, at, res, 30.0),
                    Program::Zf,
                    fps,
                ));
                id += 1;
            }
        }
    }
    out
}

/// GCL with bench-friendly exact-solve options. `quant` is coarser than the
/// default so the calibrated graphs stay small enough to probe exhaustively;
/// every run in a section uses the same options except `max_graph_nodes`.
fn config(max_graph_nodes: usize) -> PlannerConfig {
    let mut cfg = PlannerConfig::gcl();
    cfg.solve_opts = SolveOptions {
        quant: 30,
        max_graph_nodes,
        max_milp_vars: 20_000,
        milp: MilpOptions { max_nodes: 20_000, ..Default::default() },
        milp_node_scale: 10_000_000,
        exact: true,
    };
    cfg
}

fn catalog() -> Catalog {
    Catalog::builtin().restrict(
        Some(&["c4.2xlarge", "c4.8xlarge", "g2.2xlarge", "g3.8xlarge"]),
        None,
    )
}

fn lenient() -> bool {
    std::env::var_os("BENCH_LENIENT_TIMING").is_some()
}

/// A plan's exact phase "completed" when no component fell back and every
/// component proved optimality.
fn exact_complete(plan: &Plan) -> bool {
    plan.pipeline.components_fallback == 0
        && plan.pipeline.components_proven == plan.pipeline.components
}

/// Drop every 80th request (≈1.25%), spreading the count delta across all
/// metros so each component stays within the delta-solve bound.
fn primed(base: &[StreamRequest]) -> Vec<StreamRequest> {
    base.iter()
        .enumerate()
        .filter(|(i, _)| i % 80 != 0)
        .map(|(_, r)| r.clone())
        .collect()
}

/// Per-stage wall-clock of one run as a JSON object.
fn stage_ms(plan: &Plan) -> Value {
    Value::obj(vec![
        ("eligibility", Value::num(plan.pipeline.elig_ms)),
        ("build", Value::num(plan.pipeline.build_ms)),
        ("solve", Value::num(plan.pipeline.solve_ms)),
        ("expand", Value::num(plan.pipeline.expand_ms)),
    ])
}

fn parity(out: &mut Vec<Value>, lp: &mut (u64, u64)) {
    println!("== 10k streams: warm delta re-plan vs cold plan (GCL) ==");
    let catalog = catalog();
    let cfg = config(SolveOptions::default().max_graph_nodes);
    let mut strict_scenarios = 0usize;
    let mut delta_hits_total = 0usize;
    let mut largest = (0.0f64, 0.0f64); // (cold ms, warm ms) of last scenario
    for fps in [20.0, 24.0, 28.0] {
        let base = requests_for(&easy_metros(1_250, fps));
        assert_eq!(base.len(), 10_000);
        let prime = primed(&base);

        let t0 = Instant::now();
        let cold = plan_with_context(&catalog, &cfg, &base, &mut PlanContext::new()).unwrap();
        let cold_ms = t0.elapsed().as_secs_f64() * 1e3;

        let mut ctx = PlanContext::new();
        plan_with_context(&catalog, &cfg, &prime, &mut ctx).unwrap();
        let t1 = Instant::now();
        let warm = plan_with_context(&catalog, &cfg, &base, &mut ctx).unwrap();
        let warm_ms = t1.elapsed().as_secs_f64() * 1e3;

        // The ≈1% count drift must ride the near-match memo, not cold-solve.
        assert!(
            warm.pipeline.delta_solve_hits > 0,
            "fps {fps}: no delta-solve reuse on a pure count drift: {:?}",
            warm.pipeline
        );
        delta_hits_total += warm.pipeline.delta_solve_hits;
        lp.0 += warm.pipeline.lp_warm_resumes as u64;
        lp.1 += warm.pipeline.lp_cold_solves as u64;

        // Drift-proportional front-end. The deterministic bars first: the
        // cold plan has no previous slice; the warm re-plan reuses exactly
        // the surviving requests (the every-80th drop returns, so the
        // drift is the 125 re-added cameras) and its artifacts are
        // bit-identical by construction (property-tested in the suite).
        assert_eq!(cold.pipeline.front_unchanged, 0);
        assert_eq!(
            warm.pipeline.front_unchanged,
            prime.len(),
            "fps {fps}: every surviving request must ride the dirty index"
        );
        assert_eq!(warm.pipeline.front_changed, base.len() - prime.len());
        // The wall-clock bar: the warm front-end does map lookups where the
        // cold one recomputes 10k per-camera coverage circles (haversine ×
        // regions — a multi-ms floor on any hardware), so 5× holds with a
        // wide margin even on noisy shared runners.
        let cold_front = cold.pipeline.front_end_ms();
        let warm_front = warm.pipeline.front_end_ms();
        assert!(
            warm_front * 5.0 <= cold_front,
            "fps {fps}: warm front-end {warm_front:.2} ms not 5x under cold {cold_front:.2} ms"
        );

        // Deterministic cost bars.
        assert!(
            warm.cost_per_hour <= cold.cost_per_hour + 1e-6,
            "fps {fps}: warm {} worse than cold {}",
            warm.cost_per_hour,
            cold.cost_per_hour
        );
        // Equality bar at solver tolerance: both sides are proven optima of
        // the same problem, but summing ~2k bin costs in different decode
        // orders legitimately drifts by a few 1e-10.
        let strict = exact_complete(&cold) && exact_complete(&warm);
        if strict {
            strict_scenarios += 1;
            assert!(
                (warm.cost_per_hour - cold.cost_per_hour).abs() < 1e-6,
                "fps {fps}: warm re-plan {} != cold exact {}",
                warm.cost_per_hour,
                cold.cost_per_hour
            );
        }
        println!(
            "fps {fps:>4}: cold {cold_ms:8.1} ms  warm {warm_ms:8.1} ms  \
             ({:.1}x)  front {cold_front:7.2} -> {warm_front:6.2} ms ({:.0}x)  \
             $/h {:.3}  delta_hits {}  exact_complete {strict}",
            cold_ms / warm_ms.max(1e-9),
            cold_front / warm_front.max(1e-9),
            warm.cost_per_hour,
            warm.pipeline.delta_solve_hits
        );
        out.push(Value::obj(vec![
            ("streams", Value::num(base.len() as f64)),
            ("fps", Value::num(fps)),
            ("cold_ms", Value::num(cold_ms)),
            ("warm_ms", Value::num(warm_ms)),
            ("speedup", Value::num(cold_ms / warm_ms.max(1e-9))),
            ("cold_front_ms", Value::num(cold_front)),
            ("warm_front_ms", Value::num(warm_front)),
            ("front_speedup", Value::num(cold_front / warm_front.max(1e-9))),
            ("front_unchanged", Value::num(warm.pipeline.front_unchanged as f64)),
            ("front_changed", Value::num(warm.pipeline.front_changed as f64)),
            ("cold_stage_ms", stage_ms(&cold)),
            ("warm_stage_ms", stage_ms(&warm)),
            ("cold_usd_per_hour", Value::num(cold.cost_per_hour)),
            ("warm_usd_per_hour", Value::num(warm.cost_per_hour)),
            ("reuse_ratio", Value::num(warm.pipeline.reuse_ratio())),
            ("delta_solve_hits", Value::num(warm.pipeline.delta_solve_hits as f64)),
            ("components", Value::num(warm.pipeline.components as f64)),
            ("cold_exact_complete", Value::Bool(exact_complete(&cold))),
            (
                "warm_equals_cold",
                Value::Bool((warm.cost_per_hour - cold.cost_per_hour).abs() < 1e-6),
            ),
        ]));
        largest = (cold_ms, warm_ms);
    }
    assert!(
        strict_scenarios >= 1,
        "no parity scenario completed its exact phase — the bar is vacuous"
    );
    assert!(delta_hits_total >= 3);
    // Wall-clock: the warm 10k re-plan should beat the cold plan where solve
    // time dominates; recorded always, gated only on dedicated hardware.
    if largest.0 >= 50.0 && largest.1 >= largest.0 {
        let msg = format!(
            "10k warm re-plan ({:.1} ms) not faster than cold ({:.1} ms)",
            largest.1, largest.0
        );
        assert!(lenient(), "{msg}");
        println!("WARNING (not asserted, BENCH_LENIENT_TIMING set): {msg}");
    }
}

fn exact_recovery(out: &mut Vec<(&'static str, Value)>) {
    println!("\n== Exact-phase recovery under adaptive budgets (10k+ streams) ==");
    let catalog = catalog();
    // Five single-tier metros in pairwise-disjoint region clusters (each a
    // one-group component with a tiny graph), plus one hard metro: Tokyo
    // with six GPU-bound fps tiers, whose joint arc-flow state space dwarfs
    // every single-group component — the calibration below relies on that
    // dominance.
    let mut metros: Vec<Metro> = [
        ("Ohio", GeoPoint::new(39.96, -82.99)),
        ("Ireland", GeoPoint::new(53.34, -6.27)),
        ("Singapore", GeoPoint::new(1.35, 103.82)),
        ("Sydney", GeoPoint::new(-33.87, 151.21)),
        ("SaoPaulo", GeoPoint::new(-23.55, -46.63)),
    ]
    .into_iter()
    .map(|(name, at)| Metro {
        name,
        at,
        per_tier: 1_600,
        tiers: vec![(20.0, Resolution::VGA)],
    })
    .collect();
    metros.push(Metro {
        name: "Tokyo",
        at: GeoPoint::new(35.68, 139.69),
        per_tier: 350,
        tiers: (0..6).map(|i| (23.0 + i as f64, Resolution::VGA)).collect(),
    });
    let requests = requests_for(&metros);
    assert_eq!(requests.len(), 10_100);

    // Probe: generous budgets measure each component's true arc-flow need.
    let mut probe_ctx = PlanContext::new();
    let probe =
        plan_with_context(&catalog, &config(2_000_000), &requests, &mut probe_ctx).unwrap();
    assert!(
        exact_complete(&probe),
        "probe run must complete its exact phase: {:?}",
        probe.pipeline
    );
    let needs: Vec<usize> = probe_ctx
        .component_telemetry()
        .iter()
        .map(|t| t.graph_nodes)
        .collect();
    assert!(
        needs.len() >= 2 && needs[0] > needs[1] + 8,
        "workload did not produce a dominant hard component: {needs:?}"
    );
    // Pin the static seed budget strictly between the hardest component and
    // the rest (with a few nodes of margin below the hard need, so the
    // ±1-node edge semantics of the cumulative budget check cannot flip the
    // expected fallback).
    let static_budget = needs[1] + (needs[0] - needs[1]) / 2;

    // Static budgets (the seed behaviour): the hard metro falls back.
    let mut static_ctx = PlanContext::new();
    let static_plan =
        plan_with_context(&catalog, &config(static_budget), &requests, &mut static_ctx).unwrap();
    let static_fallbacks = static_plan.pipeline.components_fallback;
    assert!(
        static_fallbacks >= 1,
        "static budget {static_budget} was expected to starve the hard metro: {needs:?}"
    );

    // Adaptive budgets: same static seed, but the context has seen the
    // fallback — the next (drifted) re-plan escalates the hard component
    // from the donated pool and recovers the exact solve.
    let mut adaptive_ctx = PlanContext::new();
    let cfg = config(static_budget);
    plan_with_context(&catalog, &cfg, &primed(&requests), &mut adaptive_ctx).unwrap();
    let adaptive = plan_with_context(&catalog, &cfg, &requests, &mut adaptive_ctx).unwrap();
    let donated = adaptive.pipeline.budget_donated_nodes;
    let recovered = adaptive.pipeline.components_fallback == 0;
    assert!(
        recovered,
        "adaptive budgets failed to recover the exact phase: donated {donated}, {:?}",
        adaptive.pipeline
    );
    assert!(donated > 0, "recovery must be funded by the pool");
    assert!(
        adaptive.cost_per_hour <= static_plan.cost_per_hour + 1e-9,
        "adaptive {} worse than static {}",
        adaptive.cost_per_hour,
        static_plan.cost_per_hour
    );
    println!(
        "needs {:?}  static_budget {static_budget}  static_fallbacks {static_fallbacks}  \
         recovered {recovered}  donated {donated}  $/h static {:.3} -> adaptive {:.3}",
        &needs[..needs.len().min(4)],
        static_plan.cost_per_hour,
        adaptive.cost_per_hour
    );
    out.push((
        "exact_recovery",
        Value::obj(vec![
            ("streams", Value::num(requests.len() as f64)),
            ("components", Value::num(probe.pipeline.components as f64)),
            ("probe_need_max", Value::num(needs[0] as f64)),
            ("probe_need_second", Value::num(needs[1] as f64)),
            ("static_budget", Value::num(static_budget as f64)),
            ("static_fallbacks", Value::num(static_fallbacks as f64)),
            (
                "adaptive_fallbacks",
                Value::num(adaptive.pipeline.components_fallback as f64),
            ),
            ("budget_donated_nodes", Value::num(donated as f64)),
            ("static_usd_per_hour", Value::num(static_plan.cost_per_hour)),
            ("adaptive_usd_per_hour", Value::num(adaptive.cost_per_hour)),
            ("probe_usd_per_hour", Value::num(probe.cost_per_hour)),
            ("recovered", Value::Bool(recovered)),
        ]),
    ));
}

fn main() {
    let mut parity_rows = Vec::new();
    let mut extra = Vec::new();
    let mut lp = (0u64, 0u64);

    parity(&mut parity_rows, &mut lp);
    exact_recovery(&mut extra);

    println!("\nlp_reuse: {} warm resumes vs {} cold node-LP solves", lp.0, lp.1);
    let mut pairs = vec![
        ("bench", Value::str("scale")),
        ("parity", Value::arr(parity_rows)),
        (
            "lp_reuse",
            Value::obj(vec![
                ("lp_warm_resumes", Value::num(lp.0 as f64)),
                ("lp_cold_solves", Value::num(lp.1 as f64)),
            ]),
        ),
    ];
    pairs.extend(extra);
    let doc = Value::obj(pairs);
    let path = "BENCH_scale.json";
    std::fs::write(path, camflow::util::json::to_string_pretty(&doc))
        .expect("write BENCH_scale.json");
    println!("wrote {path}");
    println!("\nbench_scale OK");
}
