//! Packing machinery benchmarks:
//!   * the paper's sidebar arc-flow instance (graph build + compression),
//!   * Fig 2's three-streams / four-instances example,
//!   * FFD vs exact (arc-flow + B&B) cost gap and runtime scaling.

use camflow::bench::{Bench, Table};
use camflow::catalog::Dims;
use camflow::packing::arcflow::{self, QuantItem};
use camflow::packing::heuristic::{self, simple_problem};
use camflow::packing::mcvbp::{solve, SolveOptions};
use camflow::packing::{BinType, ItemGroup, PackingProblem};
use camflow::util::Rng;

fn sidebar() {
    println!("== Sidebar: arc-flow graph for truck (7,3); A(5,1)x1 B(3,1)x1 C(2,1)x2 ==");
    let cap = vec![7, 3];
    let items = vec![
        QuantItem { sizes: vec![5, 1], count: 1 },
        QuantItem { sizes: vec![3, 1], count: 1 },
        QuantItem { sizes: vec![2, 1], count: 2 },
    ];
    let g = arcflow::build(&cap, &items, 10_000).unwrap();
    let (cg, stats) = arcflow::compress(&g);
    let packs = arcflow::enumerate_packings(&cg, 3);
    let mut t = Table::new(&["Stage", "Nodes", "Arcs"]);
    t.row(&["raw".into(), stats.nodes_before.to_string(), stats.arcs_before.to_string()]);
    t.row(&["compressed".into(), stats.nodes_after.to_string(), stats.arcs_after.to_string()]);
    t.print();
    println!("feasible single-truck packings: {packs:?}");
    println!(
        "compression: {:.0}% nodes, {:.0}% arcs retained\n",
        stats.node_ratio() * 100.0,
        stats.arc_ratio() * 100.0
    );
    let max_boxes: usize = packs.iter().map(|p| p.iter().sum()).max().unwrap();
    assert_eq!(max_boxes, 3, "best single truck holds B + 2C");
}

fn fig2() {
    println!("== Fig 2: three stream types x four instance choices ==");
    // Streams A, B, C with (CPU, mem, GPU) demands; four instance choices.
    let bins = vec![
        BinType { label: "I1 cpu-small".into(), capacity: Dims::new(4.0, 8.0, 0.0, 0.0), cost: 1.0, type_idx: 0, region_idx: 0, has_gpu: false },
        BinType { label: "I2 cpu-big".into(), capacity: Dims::new(16.0, 32.0, 0.0, 0.0), cost: 3.0, type_idx: 1, region_idx: 0, has_gpu: false },
        BinType { label: "I3 gpu".into(), capacity: Dims::new(8.0, 16.0, 1.0, 8.0), cost: 2.5, type_idx: 2, region_idx: 0, has_gpu: true },
        BinType { label: "I4 gpu-big".into(), capacity: Dims::new(16.0, 64.0, 4.0, 32.0), cost: 7.0, type_idx: 3, region_idx: 0, has_gpu: true },
    ];
    let mk = |cpu: f64, mem: f64, gcpu: f64, gmem: f64, ggpu: f64, count: usize, label: &str| ItemGroup {
        label: label.into(),
        count,
        demand_per_bin: vec![
            Some(Dims::new(cpu, mem, 0.0, 0.0)),
            Some(Dims::new(cpu, mem, 0.0, 0.0)),
            Some(Dims::new(gcpu, gmem, ggpu, 2.0)),
            Some(Dims::new(gcpu, gmem, ggpu, 2.0)),
        ],
    };
    let items = vec![
        mk(2.0, 3.0, 0.4, 1.0, 0.3, 2, "A"),
        mk(3.0, 2.0, 0.5, 1.0, 0.4, 2, "B"),
        mk(1.0, 1.5, 0.3, 0.8, 0.2, 2, "C"),
    ];
    let problem = PackingProblem::new(items, bins);
    let (packing, stats) = solve(&problem, &SolveOptions::default()).unwrap();
    let mut t = Table::new(&["Bin", "A", "B", "C", "cost"]);
    for b in &packing.bins {
        t.row(&[
            problem.bins[b.bin_type].label.clone(),
            b.counts[0].to_string(),
            b.counts[1].to_string(),
            b.counts[2].to_string(),
            format!("{:.1}", problem.bins[b.bin_type].cost),
        ]);
    }
    t.print();
    println!(
        "total ${:.2}/h via {:?} ({} B&B nodes)\n",
        packing.total_cost(&problem),
        stats.method,
        stats.milp_nodes
    );
    packing.validate(&problem).unwrap();
}

fn scaling() {
    println!("== FFD vs exact: cost gap and runtime scaling ==");
    let bench = Bench::new(1, 5);
    let mut t = Table::new(&["streams", "groups", "FFD $", "exact $", "gap", "FFD ms", "exact ms", "graph nodes", "milp vars"]);
    let mut rng = Rng::new(2024);
    for &(groups, per) in &[(2usize, 4usize), (3, 6), (4, 8), (5, 10), (6, 12)] {
        let items: Vec<(f64, f64, usize)> = (0..groups)
            .map(|_| (rng.range_f64(0.5, 5.5), rng.range_f64(0.5, 6.0), per))
            .collect();
        let p = simple_problem(
            &items,
            &[(8.0, 15.0, 0.419), (16.0, 30.0, 0.796), (36.0, 60.0, 1.591)],
        );
        let ffd = heuristic::first_fit_decreasing(&p).unwrap();
        let tf = bench.run("ffd", || {
            let _ = heuristic::first_fit_decreasing(&p);
        });
        let (exact, stats) = solve(&p, &SolveOptions::default()).unwrap();
        let te = bench.run("exact", || {
            let _ = solve(&p, &SolveOptions::default());
        });
        let fc = ffd.total_cost(&p);
        let ec = exact.total_cost(&p);
        t.row(&[
            (groups * per).to_string(),
            groups.to_string(),
            format!("{fc:.3}"),
            format!("{ec:.3}"),
            format!("{:.0}%", (1.0 - ec / fc) * 100.0),
            format!("{:.2}", tf.mean_ms),
            format!("{:.1}", te.mean_ms),
            stats.graph_nodes_after.to_string(),
            stats.milp_vars.to_string(),
        ]);
        assert!(ec <= fc + 1e-9);
    }
    t.print();
}

fn main() {
    sidebar();
    fig2();
    scaling();
    println!("\nbench_packing OK");
}
