//! Table I — prices of EC2 and Azure instances at different locations.
//!
//! Regenerates the paper's Table I rows from the built-in catalog and checks
//! them against the published values.

use camflow::bench::Table;
use camflow::catalog::Catalog;

fn cell(c: &Catalog, ty: &str, region: &str) -> String {
    let t = c.type_by_name(ty).expect("type");
    let r = c.region_by_id(region).expect("region");
    match c.price(t, r) {
        Some(p) => format!("{p:.3}"),
        None => "N/A".to_string(),
    }
}

fn main() {
    let c = Catalog::builtin();
    println!("== Table I: prices of cloud instances at different locations ==\n");

    let mut ec2 = Table::new(&["Vendor", "Instance", "Cores", "Memory (GiB)", "GPU", "Virginia", "London", "Singapore"]);
    for ty in ["c4.2xlarge", "c4.8xlarge", "g3.8xlarge"] {
        let t = c.type_by_name(ty).unwrap();
        let cap = c.types[t].capacity;
        ec2.row(&[
            "EC2".into(),
            ty.into(),
            format!("{}", cap.vcpus as u64),
            format!("{}", cap.mem_gib),
            format!("{}", cap.gpus as u64),
            cell(&c, ty, "us-east-1"),
            cell(&c, ty, "eu-west-2"),
            cell(&c, ty, "ap-southeast-1"),
        ]);
    }
    ec2.print();

    let mut az = Table::new(&["Vendor", "Instance", "Cores", "Memory (GiB)", "GPU", "US East", "West Europe", "East Asia"]);
    for ty in ["D8_v3", "NC24r"] {
        let t = c.type_by_name(ty).unwrap();
        let cap = c.types[t].capacity;
        az.row(&[
            "Azure".into(),
            ty.into(),
            format!("{}", cap.vcpus as u64),
            format!("{}", cap.mem_gib),
            format!("{}", cap.gpus as u64),
            cell(&c, ty, "az-us-east"),
            cell(&c, ty, "az-west-europe"),
            cell(&c, ty, "az-east-asia"),
        ]);
    }
    println!();
    az.print();

    // Validation against the paper's printed numbers.
    let expected = [
        ("c4.2xlarge", "us-east-1", "0.398"),
        ("c4.2xlarge", "eu-west-2", "0.476"),
        ("c4.2xlarge", "ap-southeast-1", "0.462"),
        ("c4.8xlarge", "us-east-1", "1.591"),
        ("c4.8xlarge", "eu-west-2", "1.902"),
        ("c4.8xlarge", "ap-southeast-1", "1.848"),
        ("g3.8xlarge", "us-east-1", "2.280"),
        ("g3.8xlarge", "eu-west-2", "N/A"),
        ("g3.8xlarge", "ap-southeast-1", "3.340"),
        ("D8_v3", "az-us-east", "0.384"),
        ("D8_v3", "az-west-europe", "0.480"),
        ("D8_v3", "az-east-asia", "0.625"),
        ("NC24r", "az-us-east", "3.960"),
        ("NC24r", "az-west-europe", "5.132"),
        ("NC24r", "az-east-asia", "N/A"),
    ];
    let mut ok = 0;
    for (ty, rg, want) in expected {
        let got = cell(&c, ty, rg);
        assert_eq!(got, want, "{ty}@{rg}");
        ok += 1;
    }
    println!("\nAll {ok}/15 Table-I cells match the paper.");
    println!(
        "Paper's 63% observation: D8_v3 East-Asia/US-East = {:.2}",
        0.625 / 0.384
    );
}
