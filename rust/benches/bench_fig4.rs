//! Fig 4 — instances needed vs desired frame rate for six worldwide cameras.
//!
//! The paper: at high frame rates the RTT circles around the cameras do not
//! overlap any common data center, so six instances are needed; at lower
//! rates the circles grow and three (or fewer) instances suffice. This bench
//! computes the minimal number of instance sites (exact set cover over the
//! catalog's regions) across a frame-rate sweep.

use camflow::bench::Table;
use camflow::cameras::scenarios::fig4_cameras;
use camflow::catalog::Catalog;
use camflow::geo;

/// Exact minimum set cover (6 cameras -> trivially small search).
fn min_cover(masks: &[u64], universe: u64) -> usize {
    // masks: per region, the set of cameras it covers.
    let mut best = usize::MAX;
    // BFS over number of regions.
    fn rec(masks: &[u64], covered: u64, universe: u64, used: usize, best: &mut usize) {
        if covered == universe {
            *best = (*best).min(used);
            return;
        }
        if used + 1 >= *best {
            return;
        }
        // Pick an uncovered camera, try all regions covering it.
        let missing = (!covered) & universe;
        let cam = missing.trailing_zeros();
        for m in masks {
            if m & (1 << cam) != 0 {
                rec(masks, covered | m, universe, used + 1, best);
            }
        }
    }
    rec(masks, 0, universe, 0, &mut best);
    best
}

fn main() {
    let catalog = Catalog::builtin();
    let cams = fig4_cameras();
    let universe = (1u64 << cams.len()) - 1;

    let mut t = Table::new(&["fps", "RTT budget ms", "radius km", "min instances", "example regions"]);
    let mut results = Vec::new();
    for fps in [30.0, 25.0, 20.0, 16.0, 12.0, 8.0, 6.0, 4.0, 2.0, 1.0] {
        let masks: Vec<u64> = catalog
            .regions
            .iter()
            .map(|r| {
                cams.iter()
                    .enumerate()
                    .filter(|(_, c)| geo::reachable(&c.location, &r.location, fps))
                    .fold(0u64, |m, (i, _)| m | (1 << i))
            })
            .collect();
        let infeasible = (0..cams.len()).any(|i| masks.iter().all(|m| m & (1 << i) == 0));
        let n = if infeasible { usize::MAX } else { min_cover(&masks, universe) };
        // A witness cover for display: greedy.
        let mut covered = 0u64;
        let mut witness = Vec::new();
        while covered != universe && !infeasible {
            let (ri, m) = masks
                .iter()
                .enumerate()
                .max_by_key(|(_, m)| (*m & !covered).count_ones())
                .map(|(i, m)| (i, *m))
                .unwrap();
            if m & !covered == 0 {
                break;
            }
            covered |= m;
            witness.push(catalog.regions[ri].id);
        }
        t.row(&[
            format!("{fps}"),
            format!("{:.0}", geo::rtt_budget_ms(fps)),
            format!("{:.0}", geo::coverage_radius_km(fps)),
            if infeasible { "-".into() } else { n.to_string() },
            witness.join(", "),
        ]);
        results.push((fps, n));
    }
    t.print();

    // Shape checks (the paper's (a) high fps -> 6, (b) lower fps -> 3).
    let at = |fps: f64| results.iter().find(|r| r.0 == fps).unwrap().1;
    assert_eq!(at(30.0), 6, "at 30 fps each camera needs its own instance");
    assert!(
        (2..=3).contains(&at(8.0)),
        "by 8 fps a few instances cover all cameras (got {})",
        at(8.0)
    );
    let counts: Vec<usize> = results.iter().map(|r| r.1).collect();
    assert!(
        counts.windows(2).all(|w| w[0] >= w[1]),
        "instance count must not increase as fps drops: {counts:?}"
    );
    println!("\nShape OK: 6 instances at 30 fps -> {} at 8 fps -> {} at 1 fps.", at(8.0), at(1.0));
}
