//! Spot-priced deferred-analytics bars (replay in `camflow::bench::spot`):
//!
//! * the spot-enabled replay's executed backfill spend is **strictly below**
//!   the on-demand-only replay's, with the live fleets costing the same
//!   (live streams never ride revocable capacity),
//! * the deadline-miss rate under seeded preemption storms stays ≤ 1%,
//! * revocations fire in the spot replay (and cannot in the on-demand-only
//!   one), the zero-preemption hour re-plans bit-identically, and a forced
//!   single-lane revocation re-homes only the stranded placements.
//!
//! All bars are deterministic (fixed seeds, no threads, no wall clock) and
//! asserted inside `camflow::bench::spot::run`, so this binary and
//! `tests/integration.rs` gate on exactly the same invariants. The only
//! wall-clock number is the recorded replay timing, which is never asserted.
//!
//! Emits `BENCH_spot.json` — validated against
//! `camflow::bench::schema::SPOT` before writing — so savings and miss
//! rates are tracked across PRs.

use camflow::bench::{schema, Bench, Table};
use camflow::util::json::Value;

fn main() {
    println!("== Spot-priced backfill: diurnal replay with preemption storms ==");
    let bench = Bench::new(1, 3);
    let timing = bench.run("spot + on-demand replays", || {
        let _ = camflow::bench::spot::run();
    });
    let o = camflow::bench::spot::run();

    let mut t = Table::new(&["config", "backfill $", "live $", "revoked", "misses", "units"]);
    t.row(&[
        "spot-enabled".to_string(),
        format!("{:.3}", o.spot.backfill_usd),
        format!("{:.3}", o.spot.live_usd),
        format!("{}", o.spot.revocations),
        format!("{}", o.spot.deadline_misses),
        format!("{}", o.spot.completed_units),
    ]);
    t.row(&[
        "on-demand only".to_string(),
        format!("{:.3}", o.od_only.backfill_usd),
        format!("{:.3}", o.od_only.live_usd),
        format!("{}", o.od_only.revocations),
        format!("{}", o.od_only.deadline_misses),
        format!("{}", o.od_only.completed_units),
    ]);
    t.print();
    println!(
        "savings {:.1}%  miss rate {:.4}  rehomed {}  spot rounds {}  ({:.0} ms per replay pair)",
        o.savings_frac * 100.0,
        o.miss_rate,
        o.spot.rehomed_items,
        o.spot.spot_rounds,
        timing.mean_ms
    );

    let doc = Value::obj(vec![
        ("bench", Value::str("spot")),
        ("spot", o.to_json()),
        ("loop_ms", Value::num(timing.mean_ms)),
    ]);
    schema::validate(&doc, &schema::SPOT)
        .unwrap_or_else(|e| panic!("BENCH_spot.json schema drift: {e}"));
    let path = "BENCH_spot.json";
    std::fs::write(path, camflow::util::json::to_string_pretty(&doc))
        .expect("write BENCH_spot.json");
    println!("\nwrote {path}");
    println!("\nbench_spot OK");
}
