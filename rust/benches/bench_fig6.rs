//! Fig 6 — cost vs target frame rate for the three resource managers
//! (NL, ARMVAC, GCL) on a worldwide camera workload.
//!
//! Reproduces the figure's series and checks the paper's qualitative shape:
//! GCL cheapest everywhere; the ARMVAC/GCL and NL/GCL gaps are largest in
//! the 1–20 fps band; the paper's headline ratios (GCL up to 56% vs NL and
//! 31% vs ARMVAC) are approached on this simulated catalog.

use camflow::bench::{Bench, Table};
use camflow::cameras::scenarios::fig6_workload;
use camflow::catalog::Catalog;
use camflow::config::StrategyName;
use camflow::coordinator::Planner;

fn main() {
    let catalog = Catalog::builtin();
    let n = 30;
    let seed = 1;
    let bench = Bench::new(0, 3);

    let mut t = Table::new(&[
        "fps", "NL $/h", "ARMVAC $/h", "GCL $/h", "GCL vs NL", "GCL vs ARMVAC", "GCL solve ms",
    ]);
    let mut series = Vec::new();
    for fps in [0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 12.0, 16.0, 20.0, 25.0, 30.0] {
        let requests = fig6_workload(n, fps, seed);
        let plan_cost = |s: StrategyName| {
            Planner::new(catalog.clone(), s.to_planner_config())
                .plan(&requests)
                .expect("feasible")
                .cost_per_hour
        };
        let nl = plan_cost(StrategyName::Nl);
        let armvac = plan_cost(StrategyName::Armvac);
        let gcl = plan_cost(StrategyName::Gcl);
        let gcl_planner = Planner::new(catalog.clone(), StrategyName::Gcl.to_planner_config());
        let timing = bench.run("gcl", || {
            let _ = gcl_planner.plan(&requests);
        });
        t.row(&[
            format!("{fps}"),
            format!("{nl:.3}"),
            format!("{armvac:.3}"),
            format!("{gcl:.3}"),
            format!("{:.0}%", (1.0 - gcl / nl) * 100.0),
            format!("{:.0}%", (1.0 - gcl / armvac) * 100.0),
            format!("{:.0}", timing.mean_ms),
        ]);
        series.push((fps, nl, armvac, gcl));
    }
    t.print();

    // Shape assertions.
    for &(fps, nl, armvac, gcl) in &series {
        assert!(gcl <= nl + 1e-9, "GCL must not exceed NL at {fps} fps");
        assert!(gcl <= armvac + 1e-9, "GCL must not exceed ARMVAC at {fps} fps");
    }
    let max_vs_nl = series
        .iter()
        .map(|s| 1.0 - s.3 / s.1)
        .fold(0.0f64, f64::max);
    let max_vs_armvac = series
        .iter()
        .map(|s| 1.0 - s.3 / s.2)
        .fold(0.0f64, f64::max);
    // Mid-band (1-20 fps) gap should exceed the low-band (<1 fps) NL gap? The
    // paper's claim is about where ARMVAC struggles: check the mid-band
    // ARMVAC gap is the largest.
    let mid_gap = series
        .iter()
        .filter(|s| (1.0..=20.0).contains(&s.0))
        .map(|s| 1.0 - s.3 / s.2)
        .fold(0.0f64, f64::max);
    println!(
        "\nmax GCL saving vs NL: {:.0}% (paper: up to 56%)\nmax GCL saving vs ARMVAC: {:.0}% (paper: up to 31%), mid-band max {:.0}%",
        max_vs_nl * 100.0,
        max_vs_armvac * 100.0,
        mid_gap * 100.0
    );
    assert!(max_vs_nl > 0.15, "GCL should save substantially vs NL somewhere");
    assert!(max_vs_armvac > 0.10, "GCL should save substantially vs ARMVAC somewhere");
}
