//! LP core microbenchmark: the revised (factorized) simplex against the
//! dense-tableau reference on arc-flow-shaped LPs.
//!
//! Three component classes mirror the exact solver's real workloads:
//!   * `paper_scale` — a dozen coverage rows, tens of columns (the Fig 3-6
//!     scenarios, where either core is effectively instant),
//!   * `metro` — tens of rows, hundreds of columns (city-scale clusters),
//!   * `wide_sparse` — the *largest exact component class*: ~60 rows by
//!     ~1200 columns with ≤4 nonzeros per column, the shape arc-flow graphs
//!     produce at 10k-stream scale. Here a dense pivot sweeps the full
//!     `O(m·n)` tableau while a revised pivot costs `O(nnz + m + |etas|)`,
//!     so this class is the acceptance bar: revised throughput
//!     (iterations/sec) must be at least dense throughput.
//!
//! Every timed LP is also checked for dense==revised parity (outcome
//! variant + objective bits), so the bench doubles as a large-sample parity
//! sweep on top of the property suite.
//!
//! Emits `BENCH_solver.json` (schema documented in `lib.rs`), including the
//! `calibration` section the branch-and-bound node-budget guard's
//! `NODE_COST_ROWS_WEIGHT` constant is derived from
//! (`coordinator::budget::milp_node_cost`).

use camflow::bench::{Bench, Table};
use camflow::coordinator::budget::NODE_COST_ROWS_WEIGHT;
use camflow::solver::{
    solve_lp_dense_with_stats, solve_lp_with_stats, Lp, LpOutcome, LpStats, Op,
};
use camflow::util::json::Value;
use camflow::util::Rng;

/// One component class: `count` random covering LPs of the given shape.
struct Class {
    name: &'static str,
    rows: usize,
    cols: usize,
    nnz_per_col: usize,
    count: usize,
}

const CLASSES: [Class; 3] = [
    Class { name: "paper_scale", rows: 12, cols: 80, nnz_per_col: 3, count: 40 },
    Class { name: "metro", rows: 30, cols: 400, nnz_per_col: 4, count: 12 },
    Class { name: "wide_sparse", rows: 60, cols: 1200, nnz_per_col: 4, count: 6 },
];

/// A random covering LP: minimize positive costs over `Ge` rows with
/// nonnegative sparse columns — always feasible (scale x up) and bounded
/// (costs are positive), so both cores report `Optimal` and the timing
/// measures real pivot work, not early exits. Coefficients live on a 0.25
/// grid, far from the solver's epsilon.
fn covering_lp(rng: &mut Rng, rows: usize, cols: usize, nnz_per_col: usize) -> Lp {
    let mut lp = Lp::new(cols);
    let mut row_coeffs: Vec<Vec<(usize, f64)>> = vec![Vec::new(); rows];
    for j in 0..cols {
        lp.set_objective(j, 0.5 + rng.index(11) as f64 * 0.25); // [0.5, 3.0]
        let nnz = 1 + rng.index(nnz_per_col);
        let mut touched = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            let r = rng.index(rows);
            if touched.contains(&r) {
                continue; // keep one entry per (row, column)
            }
            touched.push(r);
            let c = 0.25 + rng.index(6) as f64 * 0.25; // [0.25, 1.5]
            row_coeffs[r].push((j, c));
        }
    }
    for (r, mut coeffs) in row_coeffs.into_iter().enumerate() {
        // A row no column touches would be infeasible; cover it cheaply.
        if coeffs.is_empty() {
            coeffs.push((r % cols, 1.0));
        }
        let rhs = 1.0 + rng.index(10) as f64; // [1, 10]
        lp.add_constraint(coeffs, Op::Ge, rhs);
    }
    lp
}

fn objective_bits(out: &LpOutcome) -> Option<u64> {
    match out {
        LpOutcome::Optimal(s) => Some(s.objective.to_bits()),
        _ => None,
    }
}

fn main() {
    let lenient = std::env::var_os("BENCH_LENIENT_TIMING").is_some();
    let bench = Bench::new(1, 3);
    let mut t = Table::new(&[
        "class", "rows", "cols", "dense ms", "revised ms", "dense it/s", "revised it/s",
        "speedup", "ftran/it", "refactor",
    ]);
    let mut classes_json = Vec::new();
    let mut wide_sparse_ok = true;
    let mut wide_sparse_msg = String::new();

    for class in &CLASSES {
        let mut rng = Rng::new(0xB_0117 + class.rows as u64);
        let lps: Vec<Lp> = (0..class.count)
            .map(|_| covering_lp(&mut rng, class.rows, class.cols, class.nnz_per_col))
            .collect();

        // Parity sweep + counter collection (untimed).
        let mut dense_stats = LpStats::default();
        let mut revised_stats = LpStats::default();
        for lp in &lps {
            let d = solve_lp_dense_with_stats(lp, &mut dense_stats).expect("dense solve");
            let r = solve_lp_with_stats(lp, &mut revised_stats).expect("revised solve");
            assert_eq!(
                objective_bits(&d),
                objective_bits(&r),
                "{}: dense and revised disagree on a covering LP",
                class.name
            );
        }

        // Timed sweeps: same LP set, whole-set wall clock per core.
        let dense_ms = bench
            .run(&format!("{} dense", class.name), || {
                for lp in &lps {
                    let _ = solve_lp_dense_with_stats(lp, &mut LpStats::default());
                }
            })
            .mean_ms;
        let revised_ms = bench
            .run(&format!("{} revised", class.name), || {
                for lp in &lps {
                    let _ = solve_lp_with_stats(lp, &mut LpStats::default());
                }
            })
            .mean_ms;

        let dense_ips = dense_stats.iterations as f64 / (dense_ms / 1000.0).max(1e-9);
        let revised_ips = revised_stats.iterations as f64 / (revised_ms / 1000.0).max(1e-9);
        let speedup = dense_ms / revised_ms.max(1e-9);
        let ftran_per_iter =
            revised_stats.ftran_ops as f64 / (revised_stats.iterations as f64).max(1.0);
        let btran_per_iter =
            revised_stats.btran_ops as f64 / (revised_stats.iterations as f64).max(1.0);

        t.row(&[
            class.name.to_string(),
            class.rows.to_string(),
            class.cols.to_string(),
            format!("{dense_ms:.2}"),
            format!("{revised_ms:.2}"),
            format!("{dense_ips:.0}"),
            format!("{revised_ips:.0}"),
            format!("{speedup:.1}x"),
            format!("{ftran_per_iter:.1}"),
            revised_stats.refactorizations.to_string(),
        ]);
        classes_json.push(Value::obj(vec![
            ("class", Value::str(class.name)),
            ("rows", Value::num(class.rows as f64)),
            ("cols", Value::num(class.cols as f64)),
            ("nnz_per_col", Value::num(class.nnz_per_col as f64)),
            ("lps", Value::num(class.count as f64)),
            ("dense_ms", Value::num(dense_ms)),
            ("revised_ms", Value::num(revised_ms)),
            ("dense_iterations", Value::num(dense_stats.iterations as f64)),
            ("revised_iterations", Value::num(revised_stats.iterations as f64)),
            ("dense_iters_per_sec", Value::num(dense_ips)),
            ("revised_iters_per_sec", Value::num(revised_ips)),
            ("speedup", Value::num(speedup)),
            ("ftran_per_iter", Value::num(ftran_per_iter)),
            ("btran_per_iter", Value::num(btran_per_iter)),
            ("refactorizations", Value::num(revised_stats.refactorizations as f64)),
            (
                "degenerate_pivots",
                Value::num(revised_stats.degenerate_pivots as f64),
            ),
        ]));

        // The acceptance bar lives on the largest exact component class:
        // revised throughput must meet or beat dense throughput there.
        // Wall-clock on shared CI runners is noisy, so BENCH_LENIENT_TIMING
        // records the ratio without gating on it.
        if class.name == "wide_sparse" && revised_ips < dense_ips {
            wide_sparse_ok = false;
            wide_sparse_msg = format!(
                "revised {revised_ips:.0} it/s < dense {dense_ips:.0} it/s on wide_sparse"
            );
        }
    }
    t.print();
    if !wide_sparse_ok {
        assert!(lenient, "{wide_sparse_msg}");
        println!("WARNING (not asserted, BENCH_LENIENT_TIMING set): {wide_sparse_msg}");
    }

    // Calibration: the branch-and-bound node guard divides its node-scale
    // grant by `milp_node_cost(vars, rows)` = min(vars, 8·rows). The dense
    // era divided by `vars` (a dense pivot sweeps every column); under the
    // revised core per-pivot cost tracks rows (basis size) and column
    // sparsity, so the divisor is capped at `NODE_COST_ROWS_WEIGHT · rows`.
    // The weight is the wide_sparse cols/rows cost ratio observed here,
    // rounded down to a conservative power of two — recorded so a future
    // re-run can re-derive it from this very file.
    let calibration = Value::obj(vec![
        ("node_cost_rows_weight", Value::num(NODE_COST_ROWS_WEIGHT as f64)),
        ("model", Value::str("milp_node_cost(vars, rows) = min(max(vars,1), max(8*rows,1))")),
        (
            "derivation",
            Value::str(
                "revised per-pivot cost scales with rows + nnz, not cols; \
                 weight = conservative floor of the wide_sparse speedup",
            ),
        ),
    ]);

    let doc = Value::obj(vec![
        ("bench", Value::str("solver")),
        ("classes", Value::arr(classes_json)),
        ("calibration", calibration),
    ]);
    let path = "BENCH_solver.json";
    std::fs::write(path, camflow::util::json::to_string_pretty(&doc))
        .expect("write BENCH_solver.json");
    println!("\nwrote {path}");
    println!("\nbench_solver OK");
}
