//! LP core microbenchmark: the revised (factorized) simplex — in both its
//! full-Dantzig and candidate-list partial-pricing modes — against the
//! dense-tableau reference on arc-flow-shaped LPs.
//!
//! Three component classes mirror the exact solver's real workloads:
//!   * `paper_scale` — a dozen coverage rows, tens of columns (the Fig 3-6
//!     scenarios, where either core is effectively instant),
//!   * `metro` — tens of rows, hundreds of columns (city-scale clusters),
//!   * `wide_sparse` — the *largest exact component class*: ~60 rows by
//!     ~1200 columns with ≤4 nonzeros per column, the shape arc-flow graphs
//!     produce at 10k-stream scale. Here a dense pivot sweeps the full
//!     `O(m·n)` tableau while a revised pivot costs `O(nnz + m + |etas|)`,
//!     and partial pricing reprices only its candidate list.
//!
//! The acceptance bar: partial-pricing throughput (iterations/sec) must be
//! at least dense throughput on **all three** classes, priced columns per
//! iteration must stay strictly below `n` on `wide_sparse`, and the
//! eta-fill watermark must respect the measured-fill bound
//! `fill_cap + rows + 1`. `BENCH_solver.json` is written *before* the
//! timing assertions run, and a regression prints an old-vs-new metric
//! table (against the previous run's JSON, when present) instead of a bare
//! panic.
//!
//! Every timed LP is also checked for parity — dense == full-Dantzig on
//! outcome variant + objective **bits**, dense == partial on objective to
//! ≤ 1e-9 — so the bench doubles as a large-sample parity sweep on top of
//! the property suite. A final section times the multi-group structural
//! delta paths (ghost embedding, mixed vanish+appear translation) against
//! cold re-solves and records their counters.
//!
//! Emits `BENCH_solver.json` (schema documented in `docs/BENCH_SCHEMAS.md`),
//! including the `calibration` section the branch-and-bound node-budget
//! guard's `NODE_COST_ROWS_WEIGHT` constant is derived from
//! (`coordinator::budget::milp_node_cost`).

use camflow::bench::{Bench, Table};
use camflow::coordinator::budget::NODE_COST_ROWS_WEIGHT;
use camflow::packing::heuristic::simple_problem;
use camflow::packing::mcvbp::{
    solve, solve_delta, DeltaHints, GhostGroup, PrevLayout, SolveOptions,
};
use camflow::solver::{
    solve_lp_dense_with_stats, solve_lp_partial_with_stats, solve_lp_with_stats, Lp, LpOutcome,
    LpStats, Op,
};
use camflow::util::json::{self, Value};
use camflow::util::Rng;

/// One component class: `count` random covering LPs of the given shape.
struct Class {
    name: &'static str,
    rows: usize,
    cols: usize,
    nnz_per_col: usize,
    count: usize,
}

const CLASSES: [Class; 3] = [
    Class { name: "paper_scale", rows: 12, cols: 80, nnz_per_col: 3, count: 40 },
    Class { name: "metro", rows: 30, cols: 400, nnz_per_col: 4, count: 12 },
    Class { name: "wide_sparse", rows: 60, cols: 1200, nnz_per_col: 4, count: 6 },
];

/// A random covering LP: minimize positive costs over `Ge` rows with
/// nonnegative sparse columns — always feasible (scale x up) and bounded
/// (costs are positive), so both cores report `Optimal` and the timing
/// measures real pivot work, not early exits. Coefficients live on a 0.25
/// grid, far from the solver's epsilon.
fn covering_lp(rng: &mut Rng, rows: usize, cols: usize, nnz_per_col: usize) -> Lp {
    let mut lp = Lp::new(cols);
    let mut row_coeffs: Vec<Vec<(usize, f64)>> = vec![Vec::new(); rows];
    for j in 0..cols {
        lp.set_objective(j, 0.5 + rng.index(11) as f64 * 0.25); // [0.5, 3.0]
        let nnz = 1 + rng.index(nnz_per_col);
        let mut touched = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            let r = rng.index(rows);
            if touched.contains(&r) {
                continue; // keep one entry per (row, column)
            }
            touched.push(r);
            let c = 0.25 + rng.index(6) as f64 * 0.25; // [0.25, 1.5]
            row_coeffs[r].push((j, c));
        }
    }
    for (r, mut coeffs) in row_coeffs.into_iter().enumerate() {
        // A row no column touches would be infeasible; cover it cheaply.
        if coeffs.is_empty() {
            coeffs.push((r % cols, 1.0));
        }
        let rhs = 1.0 + rng.index(10) as f64; // [1, 10]
        lp.add_constraint(coeffs, Op::Ge, rhs);
    }
    lp
}

fn objective_bits(out: &LpOutcome) -> Option<u64> {
    match out {
        LpOutcome::Optimal(s) => Some(s.objective.to_bits()),
        _ => None,
    }
}

fn objective_of(out: &LpOutcome) -> f64 {
    match out {
        LpOutcome::Optimal(s) => s.objective,
        _ => f64::NAN,
    }
}

/// Look up `classes[name].key` in a previously written `BENCH_solver.json`.
fn old_metric(old: Option<&Value>, class: &str, key: &str) -> Option<f64> {
    let classes = old?.get_arr("classes").ok()?;
    let entry = classes.iter().find(|c| c.get_str("class").is_ok_and(|s| s == class))?;
    entry.get_f64(key).ok()
}

fn main() {
    let lenient = std::env::var_os("BENCH_LENIENT_TIMING").is_some();
    let path = "BENCH_solver.json";
    // The previous run's metrics (CI restores the last artifact here); used
    // only to render a readable old-vs-new diff when an assertion fails.
    let old_doc = std::fs::read_to_string(path).ok().and_then(|s| json::parse(&s).ok());

    let bench = Bench::new(1, 3);
    let mut t = Table::new(&[
        "class", "rows", "cols", "dense ms", "dantzig ms", "partial ms", "dense it/s",
        "partial it/s", "speedup", "priced/it", "eta peak", "refactor",
    ]);
    let mut classes_json = Vec::new();
    let mut timing_failures: Vec<(String, String)> = Vec::new();

    for class in &CLASSES {
        let mut rng = Rng::new(0xB_0117 + class.rows as u64);
        let lps: Vec<Lp> = (0..class.count)
            .map(|_| covering_lp(&mut rng, class.rows, class.cols, class.nnz_per_col))
            .collect();

        // Parity sweep + counter collection (untimed). Full-Dantzig must
        // match dense on objective bits; partial pricing must match dense
        // objectives to ≤ 1e-9 (its full-sweep certificate guarantees an
        // exact optimum, reached through a different pivot sequence).
        let mut dense_stats = LpStats::default();
        let mut dantzig_stats = LpStats::default();
        let mut partial_stats = LpStats::default();
        for lp in &lps {
            let d = solve_lp_dense_with_stats(lp, &mut dense_stats).expect("dense solve");
            let f = solve_lp_with_stats(lp, &mut dantzig_stats).expect("dantzig solve");
            let p = solve_lp_partial_with_stats(lp, &mut partial_stats).expect("partial solve");
            assert_eq!(
                objective_bits(&d),
                objective_bits(&f),
                "{}: dense and full-Dantzig disagree on a covering LP",
                class.name
            );
            let gap = (objective_of(&d) - objective_of(&p)).abs();
            assert!(
                gap <= 1e-9,
                "{}: partial pricing off dense optimum by {gap:e}",
                class.name
            );
        }

        // Deterministic structural guarantees — checked on every run, no
        // leniency: bounded eta fill and sub-`n` pricing work per iteration.
        for (mode, st) in [("dantzig", &dantzig_stats), ("partial", &partial_stats)] {
            assert!(
                st.eta_fill_watermark <= st.eta_fill_cap + class.rows as u64 + 1,
                "{} {mode}: eta fill watermark {} exceeds cap {} + m + 1",
                class.name,
                st.eta_fill_watermark,
                st.eta_fill_cap
            );
        }
        let priced_per_iter_dantzig = dantzig_stats.priced_columns as f64
            / (dantzig_stats.pricing_iterations as f64).max(1.0);
        let priced_per_iter_partial = partial_stats.priced_columns as f64
            / (partial_stats.pricing_iterations as f64).max(1.0);
        if class.name == "wide_sparse" {
            assert!(
                priced_per_iter_partial < class.cols as f64,
                "partial pricing swept {priced_per_iter_partial:.0} columns/iteration on \
                 wide_sparse — not below n = {}",
                class.cols
            );
        }

        // Timed sweeps: same LP set, whole-set wall clock per core/mode.
        let dense_ms = bench
            .run(&format!("{} dense", class.name), || {
                for lp in &lps {
                    let _ = solve_lp_dense_with_stats(lp, &mut LpStats::default());
                }
            })
            .mean_ms;
        let dantzig_ms = bench
            .run(&format!("{} dantzig", class.name), || {
                for lp in &lps {
                    let _ = solve_lp_with_stats(lp, &mut LpStats::default());
                }
            })
            .mean_ms;
        let partial_ms = bench
            .run(&format!("{} partial", class.name), || {
                for lp in &lps {
                    let _ = solve_lp_partial_with_stats(lp, &mut LpStats::default());
                }
            })
            .mean_ms;

        let dense_ips = dense_stats.iterations as f64 / (dense_ms / 1000.0).max(1e-9);
        let dantzig_ips = dantzig_stats.iterations as f64 / (dantzig_ms / 1000.0).max(1e-9);
        let partial_ips = partial_stats.iterations as f64 / (partial_ms / 1000.0).max(1e-9);
        let speedup = dense_ms / partial_ms.max(1e-9);
        let ftran_per_iter =
            partial_stats.ftran_ops as f64 / (partial_stats.iterations as f64).max(1.0);
        let btran_per_iter =
            partial_stats.btran_ops as f64 / (partial_stats.iterations as f64).max(1.0);

        t.row(&[
            class.name.to_string(),
            class.rows.to_string(),
            class.cols.to_string(),
            format!("{dense_ms:.2}"),
            format!("{dantzig_ms:.2}"),
            format!("{partial_ms:.2}"),
            format!("{dense_ips:.0}"),
            format!("{partial_ips:.0}"),
            format!("{speedup:.1}x"),
            format!("{priced_per_iter_partial:.1}"),
            partial_stats.eta_fill_watermark.to_string(),
            partial_stats.refactorizations.to_string(),
        ]);
        classes_json.push(Value::obj(vec![
            ("class", Value::str(class.name)),
            ("rows", Value::num(class.rows as f64)),
            ("cols", Value::num(class.cols as f64)),
            ("nnz_per_col", Value::num(class.nnz_per_col as f64)),
            ("lps", Value::num(class.count as f64)),
            ("dense_ms", Value::num(dense_ms)),
            ("dantzig_ms", Value::num(dantzig_ms)),
            ("partial_ms", Value::num(partial_ms)),
            ("dense_iterations", Value::num(dense_stats.iterations as f64)),
            ("dantzig_iterations", Value::num(dantzig_stats.iterations as f64)),
            ("partial_iterations", Value::num(partial_stats.iterations as f64)),
            ("dense_iters_per_sec", Value::num(dense_ips)),
            ("dantzig_iters_per_sec", Value::num(dantzig_ips)),
            ("partial_iters_per_sec", Value::num(partial_ips)),
            ("speedup_partial", Value::num(speedup)),
            ("priced_cols_per_iter_dantzig", Value::num(priced_per_iter_dantzig)),
            ("priced_cols_per_iter_partial", Value::num(priced_per_iter_partial)),
            ("full_sweeps_partial", Value::num(partial_stats.full_sweeps as f64)),
            ("ftran_per_iter", Value::num(ftran_per_iter)),
            ("btran_per_iter", Value::num(btran_per_iter)),
            ("refactorizations", Value::num(partial_stats.refactorizations as f64)),
            ("eta_fill_watermark", Value::num(partial_stats.eta_fill_watermark as f64)),
            ("eta_fill_cap", Value::num(partial_stats.eta_fill_cap as f64)),
            ("degenerate_pivots", Value::num(partial_stats.degenerate_pivots as f64)),
        ]));

        // The acceptance bar now covers every component class: partial
        // pricing must meet or beat dense throughput everywhere. Wall-clock
        // on shared CI runners is noisy, so BENCH_LENIENT_TIMING records the
        // ratio without gating on it.
        if partial_ips < dense_ips {
            timing_failures.push((
                class.name.to_string(),
                format!("partial {partial_ips:.0} it/s < dense {dense_ips:.0} it/s"),
            ));
        }
    }
    t.print();

    // Multi-group structural delta paths: ghost embedding of two vanished
    // groups, then a mixed vanish+appear re-plan, each timed against the
    // cold re-solve of the same shrunken/shifted problem.
    let opts = SolveOptions::default();
    let prev = simple_problem(
        &[(2.0, 1.0, 5), (3.0, 2.0, 3), (1.5, 0.8, 4), (2.5, 1.2, 2)],
        &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)],
    );
    let (_, prev_st) = solve(&prev, &opts).expect("seed solve");
    let ghost_of = |g: usize, position: usize| GhostGroup {
        position,
        demand_bits: prev.items[g]
            .demand_per_bin
            .iter()
            .map(|d| d.map(|dims| dims.as_array().map(f64::to_bits)))
            .collect(),
        count: prev.items[g].count,
    };
    let mut delta_json = Vec::new();
    let mut dt = Table::new(&[
        "scenario", "cold ms", "delta ms", "speedup", "ghosts", "appeared", "cost delta",
    ]);

    // Scenario 1: groups 1 and 3 vanish — pure multi-ghost embedding.
    let vanish_now = simple_problem(
        &[(2.0, 1.0, 5), (1.5, 0.8, 4)],
        &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)],
    );
    let vanish_hints = DeltaHints {
        root_basis: prev_st.root_basis.clone(),
        branch_order: prev_st.branch_order.clone(),
        ghosts: vec![ghost_of(1, 1), ghost_of(3, 3)],
        appeared: None,
    };
    // Scenario 2: group 1 vanishes AND a 2.5-core group appears — ghost
    // plus block-basis translation over the augmented item list
    // [old0, ghost(old1), appeared, old2, old3].
    let mixed_now = simple_problem(
        &[(2.0, 1.0, 5), (2.5, 1.1, 3), (1.5, 0.8, 4), (2.5, 1.2, 2)],
        &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)],
    );
    let mixed_hints = DeltaHints {
        root_basis: None,
        branch_order: Vec::new(),
        ghosts: vec![ghost_of(1, 1)],
        appeared: prev_st.root_basis.clone().map(|basis| PrevLayout {
            basis,
            blocks: prev_st.var_blocks.clone(),
            num_vars: prev_st.milp_vars,
            num_groups: prev.items.len(),
            new_groups: vec![2],
        }),
    };

    for (name, now, hints) in [
        ("multi_vanish", &vanish_now, &vanish_hints),
        ("mixed_vanish_appear", &mixed_now, &mixed_hints),
    ] {
        let (cold, cold_st) = solve(now, &opts).expect("cold solve");
        let (warm, warm_st) =
            solve_delta(now, &opts, None, None, Some(hints)).expect("delta solve");
        let cost_delta = (warm.total_cost(now) - cold.total_cost(now)).abs();
        assert!(
            cost_delta <= 1e-9,
            "{name}: structural delta cost {} != cold {}",
            warm.total_cost(now),
            cold.total_cost(now)
        );
        let cold_ms = bench
            .run(&format!("structural {name} cold"), || {
                let _ = solve(now, &opts);
            })
            .mean_ms;
        let delta_ms = bench
            .run(&format!("structural {name} delta"), || {
                let _ = solve_delta(now, &opts, None, None, Some(hints));
            })
            .mean_ms;
        dt.row(&[
            name.to_string(),
            format!("{cold_ms:.2}"),
            format!("{delta_ms:.2}"),
            format!("{:.1}x", cold_ms / delta_ms.max(1e-9)),
            warm_st.structural_ghosts.to_string(),
            warm_st.structural_appeared.to_string(),
            format!("{cost_delta:.1e}"),
        ]);
        delta_json.push(Value::obj(vec![
            ("scenario", Value::str(name)),
            ("cold_ms", Value::num(cold_ms)),
            ("delta_ms", Value::num(delta_ms)),
            ("speedup", Value::num(cold_ms / delta_ms.max(1e-9))),
            ("ghost_groups", Value::num(warm_st.structural_ghosts as f64)),
            ("appeared_groups", Value::num(warm_st.structural_appeared as f64)),
            ("lp_warm", Value::num(warm_st.lp_warm as f64)),
            ("lp_cold", Value::num(warm_st.lp_cold as f64)),
            ("cost_delta", Value::num(cost_delta)),
            ("proven_optimal", Value::num(if warm_st.proven_optimal { 1.0 } else { 0.0 })),
        ]));
        // Counter check: the hints carried real multi-group structure.
        assert!(
            warm_st.structural_ghosts >= 1,
            "{name}: delta solve did not take the ghost-embedding path"
        );
    }
    println!();
    dt.print();

    // Calibration: the branch-and-bound node guard divides its node-scale
    // grant by `milp_node_cost(vars, rows)` = min(vars, 8·rows). The dense
    // era divided by `vars` (a dense pivot sweeps every column); under the
    // revised core per-pivot cost tracks rows (basis size) and column
    // sparsity, so the divisor is capped at `NODE_COST_ROWS_WEIGHT · rows`.
    // The weight is the wide_sparse cols/rows cost ratio observed here,
    // rounded down to a conservative power of two — recorded so a future
    // re-run can re-derive it from this very file.
    let calibration = Value::obj(vec![
        ("node_cost_rows_weight", Value::num(NODE_COST_ROWS_WEIGHT as f64)),
        ("model", Value::str("milp_node_cost(vars, rows) = min(max(vars,1), max(8*rows,1))")),
        (
            "derivation",
            Value::str(
                "revised per-pivot cost scales with rows + nnz, not cols; \
                 weight = conservative floor of the wide_sparse speedup",
            ),
        ),
    ]);

    // Write the artifact BEFORE the timing gate so a regressed run still
    // ships its metrics (CI uploads the file on failure too).
    let doc = Value::obj(vec![
        ("bench", Value::str("solver")),
        ("classes", Value::arr(classes_json)),
        ("structural_delta", Value::arr(delta_json)),
        ("calibration", calibration),
    ]);
    camflow::bench::schema::validate(&doc, &camflow::bench::schema::SOLVER)
        .unwrap_or_else(|e| panic!("BENCH_solver.json schema drift: {e}"));
    std::fs::write(path, camflow::util::json::to_string_pretty(&doc))
        .expect("write BENCH_solver.json");
    println!("\nwrote {path}");

    if !timing_failures.is_empty() {
        // Readable regression report: the failing classes, old vs new.
        println!("\nthroughput regression — old vs new ({path}):");
        let mut diff = Table::new(&["class", "metric", "old", "new"]);
        for (class, _) in &timing_failures {
            for key in ["dense_iters_per_sec", "partial_iters_per_sec", "speedup_partial"] {
                let old = old_metric(old_doc.as_ref(), class, key)
                    .map_or_else(|| "-".into(), |v| format!("{v:.1}"));
                let new = old_metric(Some(&doc), class, key)
                    .map_or_else(|| "-".into(), |v| format!("{v:.1}"));
                diff.row(&[class.clone(), key.to_string(), old, new]);
            }
        }
        diff.print();
        let msg: Vec<String> =
            timing_failures.iter().map(|(c, m)| format!("{c}: {m}")).collect();
        assert!(lenient, "partial pricing below dense throughput — {}", msg.join("; "));
        println!("WARNING (not asserted, BENCH_LENIENT_TIMING set): {}", msg.join("; "));
    }

    println!("\nbench_solver OK");
}
