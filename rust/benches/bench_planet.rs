//! Planet-scale sharded-planner benchmark: 100 metros across 8 region
//! basins, ~10k streams, skewed drift.
//!
//! Exercises the metro-sharded coordinator ([`ShardedPlanner`]) end to end
//! and writes `BENCH_planet.json` (fields documented in the crate docs,
//! `lib.rs`). The bars:
//!
//! * **event-driven dirtiness** (deterministic) — a no-drift round replans
//!   nothing; dropping one camera in one metro dirties exactly its basin
//!   shard; a price change fans out to all shards.
//! * **cost parity** (deterministic, certified-or-cold) — the sharded total
//!   equals the unsharded single-context plan to 1e-6 whenever every shard
//!   completed its exact phase with the Main candidate, cold, warm, and
//!   after the price fan-out. The workload is region-disjoint by
//!   construction (fps >= 32 keeps the 8 basins' coverage circles in
//!   separate region clusters), so the gate is expected to hold and is
//!   asserted, not just recorded.
//! * **multi-group structural deltas** (deterministic) — a whole fps tier
//!   swapping in one basin (every component loses its 32 fps group and
//!   gains a 44 fps group at once) must take the structural-delta warm
//!   path: ghost + appeared counters are asserted, and cost parity against
//!   the unsharded reference holds under the same certified gate.
//! * **dirty-shard-bounded wall-clock** — the all-shards price fan-out
//!   (8 cold re-plans) must cost >= 5x the one-dirty-shard warm re-plan.
//!   This is the headline event-driven win and is asserted unconditionally;
//!   the uniform-drift vs skewed-drift warm ratio is also recorded but only
//!   gated without `BENCH_LENIENT_TIMING` (dirty shards re-plan
//!   concurrently, so uniform wall-clock legitimately compresses on wide
//!   machines).

use camflow::cameras::{camera_at, StreamRequest};
use camflow::catalog::Catalog;
use camflow::coordinator::shard::{ShardedPlan, ShardedPlanner};
use camflow::coordinator::{Plan, Planner, PlannerConfig};
use camflow::geo::GeoPoint;
use camflow::packing::mcvbp::SolveOptions;
use camflow::profiles::{Program, Resolution};
use camflow::solver::MilpOptions;
use camflow::util::json::Value;
use std::time::Instant;

/// The eight basin anchors are EC2 region cities; at fps >= 32 each basin's
/// coverage circles stay inside its own region cluster, so the 100 metros
/// collapse to exactly 8 mask-disjoint shards.
const BASINS: [(&str, f64, f64); 8] = [
    ("Virginia", 38.95, -77.45),
    ("Oregon", 45.84, -119.70),
    ("Ireland", 53.34, -6.27),
    ("Singapore", 1.35, 103.82),
    ("Sydney", -33.87, 151.21),
    ("Tokyo", 35.68, 139.69),
    ("Mumbai", 19.08, 72.88),
    ("SaoPaulo", -23.55, -46.63),
];

/// Metros per basin: 4x13 + 4x12 = 100.
const METROS_PER_BASIN: [usize; 8] = [13, 13, 13, 13, 12, 12, 12, 12];

const TIERS: [f64; 3] = [32.0, 36.0, 40.0];
const CAMS_PER_TIER: usize = 34;

/// The full workload: 100 metros x 3 fps tiers x 34 cameras = 10_200
/// streams. Metro centers sit on a small grid within ~0.3 degrees of their
/// basin anchor (well inside the >= 2700 km coverage radius at 32 fps), and
/// cameras jitter ~10 m around the metro center for distinct eligibility
/// entries.
fn workload() -> Vec<StreamRequest> {
    let mut out = Vec::new();
    let mut id = 0u64;
    for (b, &(_, lat, lon)) in BASINS.iter().enumerate() {
        for metro in 0..METROS_PER_BASIN[b] {
            let center = GeoPoint::new(
                lat + 0.02 * (metro % 5) as f64,
                lon + 0.02 * (metro / 5) as f64,
            );
            for &fps in &TIERS {
                for _ in 0..CAMS_PER_TIER {
                    let at = GeoPoint::new(
                        center.lat + (id % 997) as f64 * 1e-7,
                        center.lon + (id % 1009) as f64 * 1e-7,
                    );
                    out.push(StreamRequest::new(
                        camera_at(id, BASINS[b].0, at, Resolution::VGA, 30.0),
                        Program::Zf,
                        fps,
                    ));
                    id += 1;
                }
            }
        }
    }
    out
}

fn config() -> PlannerConfig {
    let mut cfg = PlannerConfig::gcl();
    cfg.solve_opts = SolveOptions {
        quant: 30,
        max_graph_nodes: SolveOptions::default().max_graph_nodes,
        max_milp_vars: 20_000,
        milp: MilpOptions { max_nodes: 20_000, ..Default::default() },
        milp_node_scale: 10_000_000,
        exact: true,
    };
    cfg
}

fn catalog() -> Catalog {
    Catalog::builtin().restrict(
        Some(&["c4.2xlarge", "c4.8xlarge", "g2.2xlarge", "g3.8xlarge"]),
        Some(&[
            "us-east-1",
            "us-east-2",
            "us-west-1",
            "us-west-2",
            "eu-west-1",
            "eu-west-2",
            "eu-central-1",
            "ap-southeast-1",
            "ap-southeast-2",
            "ap-northeast-1",
            "ap-south-1",
            "sa-east-1",
        ]),
    )
}

fn lenient() -> bool {
    std::env::var_os("BENCH_LENIENT_TIMING").is_some()
}

fn exact_complete(plan: &Plan) -> bool {
    plan.pipeline.components_fallback == 0
        && plan.pipeline.components_proven == plan.pipeline.components
}

/// Time one sharded round.
fn round(sp: &mut ShardedPlanner, requests: &[StreamRequest]) -> (ShardedPlan, f64) {
    let t = Instant::now();
    let plan = sp.replan(requests).unwrap();
    (plan, t.elapsed().as_secs_f64() * 1e3)
}

/// Unsharded reference: one cold single-context GCL plan.
fn unsharded(catalog: &Catalog, requests: &[StreamRequest]) -> Plan {
    Planner::new(catalog.clone(), config()).plan_single(requests).unwrap()
}

/// Assert the sharded==unsharded parity bar under its certified gate.
fn assert_parity(label: &str, sharded: &ShardedPlan, reference: &Plan) -> bool {
    let gated = sharded.exact_complete() && sharded.all_main() && exact_complete(reference);
    assert!(
        gated,
        "{label}: parity gate must hold on this region-disjoint workload \
         (exact_complete={} all_main={} ref_exact={})",
        sharded.exact_complete(),
        sharded.all_main(),
        exact_complete(reference)
    );
    let diff = (sharded.cost_per_hour - reference.cost_per_hour).abs();
    assert!(
        diff < 1e-6,
        "{label}: sharded {} != unsharded {}",
        sharded.cost_per_hour,
        reference.cost_per_hour
    );
    true
}

fn main() {
    println!("== planet: 100 metros / 8 basins / sharded planner ==");
    let catalog = catalog();
    let w0 = workload();
    assert_eq!(w0.len(), 10_200);

    let mut sp = ShardedPlanner::new(Planner::new(catalog.clone(), config()));

    // Cold: everything is dirty, all 8 basin shards plan concurrently.
    let (cold, cold_all_ms) = round(&mut sp, &w0);
    assert_eq!((cold.total_shards, cold.dirty_shards), (8, 8));
    let cold_ref = unsharded(&catalog, &w0);
    let parity_cold = assert_parity("cold", &cold, &cold_ref);
    println!(
        "cold: {cold_all_ms:9.1} ms  8/8 dirty  $/h {:.3} (unsharded {:.3})",
        cold.cost_per_hour, cold_ref.cost_per_hour
    );

    // No drift: nothing replans, the deployed plans are reused verbatim.
    let (noop, warm_noop_ms) = round(&mut sp, &w0);
    assert_eq!(noop.dirty_shards, 0);
    assert_eq!(noop.cost_per_hour, cold.cost_per_hour, "bit-identical reuse");

    // Skewed drift: one camera leaves one metro -> exactly 1 of 8 shards
    // replans, warm, through the delta-solve path.
    let w_skew: Vec<StreamRequest> = w0[1..].to_vec();
    let (skew, warm_one_dirty_ms) = round(&mut sp, &w_skew);
    assert_eq!(skew.dirty_shards, 1, "one metro's drift dirties one shard");
    let skew_stats = skew.stats_rollup();
    assert!(
        skew_stats.delta_solve_hits + skew_stats.structural_delta_hits >= 1,
        "skew drift must warm-start: {skew_stats:?}"
    );
    let skew_ref = unsharded(&catalog, &w_skew);
    let parity_skew = assert_parity("skew", &skew, &skew_ref);
    println!(
        "skew: {warm_one_dirty_ms:9.1} ms  1/8 dirty  $/h {:.3} (unsharded {:.3})",
        skew.cost_per_hour, skew_ref.cost_per_hour
    );

    // Restore the camera (dirties the same single shard again).
    let (restore, _restore_ms) = round(&mut sp, &w0);
    assert_eq!(restore.dirty_shards, 1);
    assert!(
        (restore.cost_per_hour - cold.cost_per_hour).abs() < 1e-6,
        "round-trip must restore the cold cost: {} vs {}",
        restore.cost_per_hour,
        cold.cost_per_hour
    );

    // Mixed vanish+appear: basin 0's whole 32 fps tier moves to 44 fps in
    // one re-plan. Per component the 32 fps group vanishes entirely while a
    // 44 fps group appears — the multi-group structural-delta shape: the
    // vanished group re-enters as a zero-coverage ghost and the appeared
    // group arrives by block-basis translation, in one certified-or-cold
    // warm solve (counter-asserted below).
    let per_basin: usize = TIERS.len() * CAMS_PER_TIER;
    let basin0_len = METROS_PER_BASIN[0] * per_basin;
    let mut w_mixed = w0.clone();
    for r in &mut w_mixed[..basin0_len] {
        if r.desired_fps == TIERS[0] {
            r.desired_fps = 44.0;
        }
    }
    let (mixed, warm_mixed_ms) = round(&mut sp, &w_mixed);
    assert_eq!(mixed.dirty_shards, 1, "the tier swap dirties only basin 0");
    let mixed_stats = mixed.stats_rollup();
    assert!(
        mixed_stats.structural_delta_hits >= 1
            && mixed_stats.structural_ghost_groups >= 1
            && mixed_stats.structural_appeared_groups >= 1,
        "mixed vanish+appear must take the multi-group structural-delta path: {mixed_stats:?}"
    );
    let mixed_ref = unsharded(&catalog, &w_mixed);
    let parity_mixed = assert_parity("mixed", &mixed, &mixed_ref);
    println!(
        "mixed: {warm_mixed_ms:8.1} ms  1/8 dirty  ghosts {} appeared {}  $/h {:.3} \
         (unsharded {:.3})",
        mixed_stats.structural_ghost_groups,
        mixed_stats.structural_appeared_groups,
        mixed.cost_per_hour,
        mixed_ref.cost_per_hour
    );

    // Swap the tier back (dirties the same single shard) so the uniform
    // round below starts from the deployed w0 plans, as before.
    let (unmixed, _unmix_ms) = round(&mut sp, &w0);
    assert_eq!(unmixed.dirty_shards, 1);
    assert!(
        (unmixed.cost_per_hour - cold.cost_per_hour).abs() < 1e-6,
        "tier restore must return to the cold cost: {} vs {}",
        unmixed.cost_per_hour,
        cold.cost_per_hour
    );

    // Uniform drift: one camera leaves every basin -> all 8 shards replan
    // warm, concurrently.
    let mut w_uniform = w0.clone();
    let mut drop_ids: Vec<u64> = Vec::new();
    let mut offset = 0usize;
    for &metros in &METROS_PER_BASIN {
        drop_ids.push(w0[offset].camera.id);
        offset += metros * per_basin;
    }
    w_uniform.retain(|r| !drop_ids.contains(&r.camera.id));
    assert_eq!(w_uniform.len(), w0.len() - 8);
    let (uniform, warm_uniform_ms) = round(&mut sp, &w_uniform);
    assert_eq!(uniform.dirty_shards, 8, "uniform drift dirties every shard");

    // Price fan-out: one offering's price moves -> signature change, all 8
    // shards rebuild cold.
    sp.planner.catalog.offerings[0].hourly_usd *= 1.01;
    let (fanout, price_fanout_all_ms) = round(&mut sp, &w_uniform);
    assert_eq!(fanout.dirty_shards, 8, "a price change fans out to every shard");
    assert_eq!(sp.events.price_fanouts, 1);
    let fanout_ref = unsharded(&sp.planner.catalog, &w_uniform);
    let parity_fanout = assert_parity("fanout", &fanout, &fanout_ref);
    println!(
        "fanout: {price_fanout_all_ms:7.1} ms  8/8 dirty  $/h {:.3} (unsharded {:.3})",
        fanout.cost_per_hour, fanout_ref.cost_per_hour
    );

    // The headline event-driven bar: re-planning all shards (the fan-out)
    // must cost >= 5x the one-dirty-shard warm re-plan. 8 cold solves vs one
    // warm delta re-plan — holds with a wide margin on any hardware.
    let fanout_over_skew = price_fanout_all_ms / warm_one_dirty_ms.max(1e-9);
    assert!(
        fanout_over_skew >= 5.0,
        "all-shards fan-out ({price_fanout_all_ms:.1} ms) not 5x the 1-dirty-shard \
         warm re-plan ({warm_one_dirty_ms:.1} ms)"
    );
    // Uniform warm drift touches 8x the shards of skewed drift; concurrency
    // compresses wall-clock, so this is only gated on dedicated hardware.
    let uniform_over_skew = warm_uniform_ms / warm_one_dirty_ms.max(1e-9);
    if warm_uniform_ms < warm_one_dirty_ms {
        let msg = format!(
            "uniform warm round ({warm_uniform_ms:.1} ms) under the 1-dirty round \
             ({warm_one_dirty_ms:.1} ms)"
        );
        assert!(lenient(), "{msg}");
        println!("WARNING (not asserted, BENCH_LENIENT_TIMING set): {msg}");
    }

    // Global-arbiter invariants: every shard donates into the slack ledger
    // and telemetry is labelled per shard.
    assert_eq!(sp.donors(), 8);
    let summary = sp.solver_summary();
    assert!(summary.contains("shard=us-east-1") && summary.contains("shard=total"));
    assert!(sp.fleet_report().is_some());

    println!(
        "noop {warm_noop_ms:.2} ms  skew {warm_one_dirty_ms:.1} ms  uniform \
         {warm_uniform_ms:.1} ms ({uniform_over_skew:.1}x)  fanout \
         {price_fanout_all_ms:.1} ms ({fanout_over_skew:.1}x)"
    );

    let doc = Value::obj(vec![
        ("bench", Value::str("planet")),
        ("metros", Value::num(100.0)),
        ("streams", Value::num(w0.len() as f64)),
        ("shards", Value::num(cold.total_shards as f64)),
        ("cold_all_ms", Value::num(cold_all_ms)),
        ("warm_noop_ms", Value::num(warm_noop_ms)),
        ("warm_one_dirty_ms", Value::num(warm_one_dirty_ms)),
        ("warm_mixed_ms", Value::num(warm_mixed_ms)),
        ("warm_uniform_ms", Value::num(warm_uniform_ms)),
        ("price_fanout_all_ms", Value::num(price_fanout_all_ms)),
        ("fanout_over_one_dirty", Value::num(fanout_over_skew)),
        ("uniform_over_one_dirty", Value::num(uniform_over_skew)),
        ("sharded_usd_per_hour", Value::num(cold.cost_per_hour)),
        ("unsharded_usd_per_hour", Value::num(cold_ref.cost_per_hour)),
        (
            "cost_parity",
            Value::Bool(parity_cold && parity_skew && parity_mixed && parity_fanout),
        ),
        (
            "dirty",
            Value::obj(vec![
                ("cold", Value::num(cold.dirty_shards as f64)),
                ("noop", Value::num(noop.dirty_shards as f64)),
                ("skew", Value::num(skew.dirty_shards as f64)),
                ("restore", Value::num(restore.dirty_shards as f64)),
                ("mixed", Value::num(mixed.dirty_shards as f64)),
                ("uniform", Value::num(uniform.dirty_shards as f64)),
                ("fanout", Value::num(fanout.dirty_shards as f64)),
            ]),
        ),
        (
            "structural",
            Value::obj(vec![
                (
                    "delta_hits",
                    Value::num(mixed_stats.structural_delta_hits as f64),
                ),
                (
                    "ghost_groups",
                    Value::num(mixed_stats.structural_ghost_groups as f64),
                ),
                (
                    "appeared_groups",
                    Value::num(mixed_stats.structural_appeared_groups as f64),
                ),
            ]),
        ),
        ("exact_complete", Value::Bool(cold.exact_complete())),
        ("all_main", Value::Bool(cold.all_main())),
        ("donors", Value::num(sp.donors() as f64)),
        ("lenient", Value::Bool(lenient())),
    ]);
    camflow::bench::schema::validate(&doc, &camflow::bench::schema::PLANET)
        .unwrap_or_else(|e| panic!("BENCH_planet.json schema drift: {e}"));
    let path = "BENCH_planet.json";
    std::fs::write(path, camflow::util::json::to_string_pretty(&doc))
        .expect("write BENCH_planet.json");
    println!("wrote {path}");
    println!("\nbench_planet OK");
}
