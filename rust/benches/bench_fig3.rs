//! Fig 3 — three scenarios x three instance-selection strategies.
//!
//! Regenerates the paper's Fig-3 table: number of selected non-GPU/GPU
//! instances, hourly cost, and savings per (scenario, strategy) cell, and
//! checks every cell against the published values. Also times each solve.

use camflow::bench::{Bench, Table};
use camflow::cameras::scenarios::{self, ExpectedOutcome};
use camflow::catalog::Catalog;
use camflow::coordinator::{Planner, PlannerConfig};
use camflow::util::round_dp;

fn main() {
    // The paper's Fig-3 evaluation pool: the $0.419 c4.2xlarge-class CPU box
    // and the $0.650 g2.2xlarge GPU box (us-east-2 prices).
    let catalog =
        Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
    let scns = scenarios::fig3_scenarios();
    let expected = scenarios::fig3_expected();
    let bench = Bench::new(1, 5);

    let mut table = Table::new(&[
        "Scenario", "Strategy", "Non-GPU", "GPU", "Hourly Cost (US$)", "Cost Savings", "Paper", "Match", "Solve ms",
    ]);
    let mut matches = 0;
    let mut cells = 0;

    for (si, scn) in scns.iter().enumerate() {
        // Savings baseline = the most expensive feasible strategy (paper's convention).
        let costs: Vec<Option<f64>> = [PlannerConfig::st1(), PlannerConfig::st2(), PlannerConfig::st3()]
            .into_iter()
            .map(|cfg| Planner::new(catalog.clone(), cfg).plan(&scn.requests).ok().map(|p| p.cost_per_hour))
            .collect();
        let worst = costs.iter().flatten().cloned().fold(0.0, f64::max);

        for (ci, cfg) in [PlannerConfig::st1(), PlannerConfig::st2(), PlannerConfig::st3()]
            .into_iter()
            .enumerate()
        {
            cells += 1;
            let planner = Planner::new(catalog.clone(), cfg);
            let timing = bench.run("solve", || {
                let _ = planner.plan(&scn.requests);
            });
            let result = planner.plan(&scn.requests);
            let (row, matched): ([String; 6], bool) = match (&result, &expected[si][ci]) {
                (Err(_), ExpectedOutcome::Fail) => (
                    ["Fail".into(), "Fail".into(), "Fail".into(), "Fail".into(), "Fail".into(), "yes".into()],
                    true,
                ),
                (Ok(plan), ExpectedOutcome::Selected { non_gpu, gpu, hourly_cost }) => {
                    let savings = (1.0 - plan.cost_per_hour / worst) * 100.0;
                    let m = plan.non_gpu == *non_gpu
                        && plan.gpu == *gpu
                        && round_dp(plan.cost_per_hour, 3) == *hourly_cost;
                    (
                        [
                            format!("{}", plan.non_gpu),
                            format!("{}", plan.gpu),
                            format!("${:.3}", plan.cost_per_hour),
                            format!("{savings:.0}%"),
                            format!("${hourly_cost:.3}"),
                            (if m { "yes" } else { "NO" }).into(),
                        ],
                        m,
                    )
                }
                (Ok(plan), ExpectedOutcome::Fail) => (
                    [
                        format!("{}", plan.non_gpu),
                        format!("{}", plan.gpu),
                        format!("${:.3}", plan.cost_per_hour),
                        "-".into(),
                        "Fail".into(),
                        "NO".into(),
                    ],
                    false,
                ),
                (Err(e), ExpectedOutcome::Selected { hourly_cost, .. }) => (
                    [
                        "Err".into(),
                        "Err".into(),
                        format!("{e}"),
                        "-".into(),
                        format!("${hourly_cost:.3}"),
                        "NO".into(),
                    ],
                    false,
                ),
            };
            if matched {
                matches += 1;
            }
            table.row(&[
                scn.name.clone(),
                format!("ST{}", ci + 1),
                row[0].clone(),
                row[1].clone(),
                row[2].clone(),
                row[3].clone(),
                row[4].clone(),
                row[5].clone(),
                format!("{:.1}", timing.mean_ms),
            ]);
        }
    }
    table.print();
    println!("\n{matches}/{cells} cells match the paper's Fig-3 table.");
    assert_eq!(matches, cells, "Fig-3 reproduction drifted");
}
