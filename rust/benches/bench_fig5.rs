//! Fig 5 — instance-type optimization: three instance sizes at $1, $2, $3
//! per hour holding 2, 4, and 8 streams; eight cameras to analyze.
//!
//! The paper: "The third type of instance, despite the higher cost, can
//! analyze eight data streams at the lowest cost per stream." This bench
//! builds exactly that toy catalog, packs the eight streams with both the
//! greedy and the exact packer, and prints cost-per-stream per type.

use camflow::bench::Table;
use camflow::catalog::Dims;
use camflow::packing::mcvbp::{solve, SolveOptions};
use camflow::packing::{heuristic, BinType, ItemGroup, PackingProblem};

fn bin(label: &str, streams_capacity: f64, cost: f64, idx: usize) -> BinType {
    // Capacity expressed directly in "streams" via the CPU dimension: a
    // stream demands 1.0, instance k holds `streams_capacity` (headroom is
    // folded in by using demand 0.9 per effective slot).
    BinType {
        label: label.into(),
        capacity: Dims::new(streams_capacity, streams_capacity, 0.0, 0.0),
        cost,
        type_idx: idx,
        region_idx: 0,
        has_gpu: false,
    }
}

fn main() {
    // Instance sizes from Fig 5: $1/h holds 2 streams, $2/h holds 4, $3/h
    // holds 8. A stream demands 0.9 "slots" so the 90% headroom rule leaves
    // exactly the advertised stream counts.
    let bins = vec![
        bin("small ($1)", 2.0, 1.0, 0),
        bin("medium ($2)", 4.0, 2.0, 1),
        bin("large ($3)", 8.0, 3.0, 2),
    ];
    let items = vec![ItemGroup {
        label: "stream".into(),
        count: 8,
        demand_per_bin: vec![Some(Dims::new(0.9, 0.9, 0.0, 0.0)); 3],
    }];
    let problem = PackingProblem::new(items, bins);

    // Per-type cost-per-stream table (the figure's message).
    let mut t = Table::new(&["Instance", "$/hour", "Streams/instance", "$/stream", "Cost for 8 streams"]);
    for ty in 0..3 {
        let cap = problem.effective_capacity(ty);
        let per = (cap.vcpus / 0.9).floor();
        let needed = (8.0 / per).ceil();
        t.row(&[
            problem.bins[ty].label.clone(),
            format!("{:.0}", problem.bins[ty].cost),
            format!("{per:.0}"),
            format!("{:.2}", problem.bins[ty].cost / per),
            format!("${:.0}", needed * problem.bins[ty].cost),
        ]);
    }
    t.print();

    let ffd = heuristic::first_fit_decreasing(&problem).unwrap();
    let (exact, stats) = solve(&problem, &SolveOptions::default()).unwrap();
    println!(
        "\nFFD: {} bins, ${:.0}/h | exact: {} bins, ${:.0}/h (method {:?})",
        ffd.num_bins(),
        ffd.total_cost(&problem),
        exact.num_bins(),
        exact.total_cost(&problem),
        stats.method,
    );

    // The paper's conclusion: one large instance at $3 wins.
    assert_eq!(exact.num_bins(), 1, "one large instance should hold all 8 streams");
    assert_eq!(exact.bins[0].bin_type, 2);
    assert!((exact.total_cost(&problem) - 3.0).abs() < 1e-9);
    println!("OK: the $3 large instance analyzes all eight streams at the lowest cost per stream.");
}
