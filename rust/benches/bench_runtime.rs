//! Runtime benchmarks: PJRT inference latency/throughput per (model, batch),
//! the dynamic-batching benefit, and end-to-end serving throughput.
//!
//! Requires `make artifacts`.

use camflow::bench::{Bench, Table};
use camflow::runtime::Engine;
use camflow::util::Rng;

fn frames(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * 64 * 64 * 3).map(|_| rng.f32()).collect()
}

fn main() {
    // cargo bench passes a trailing "--bench" flag; ignore dash-args.
    let artifacts = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_else(|| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")));
    println!("loading all model variants (PJRT CPU)...");
    let t0 = std::time::Instant::now();
    let engine = Engine::load(&artifacts).expect("run `make artifacts` first");
    println!("loaded {:?} in {:.1}s\n", engine.loaded_variants(), t0.elapsed().as_secs_f64());

    let bench = Bench::new(3, 15);
    let mut t = Table::new(&["model", "batch", "mean ms/batch", "p99 ms", "ms/frame", "frames/s", "MFLOP/frame"]);
    for name in ["vgg16", "zf"] {
        for &batch in &[1usize, 4, 8] {
            let input = frames(batch, 7);
            let timing = bench.run(&format!("{name} b{batch}"), || {
                let _ = engine.infer(name, batch, &input).unwrap();
            });
            let entry = engine.manifest.find(name, batch).unwrap();
            t.row(&[
                name.into(),
                batch.to_string(),
                format!("{:.2}", timing.mean_ms),
                format!("{:.2}", timing.p99_ms),
                format!("{:.2}", timing.mean_ms / batch as f64),
                format!("{:.1}", batch as f64 / (timing.mean_ms / 1e3)),
                format!("{:.1}", entry.flops_per_frame / 1e6),
            ]);
        }
    }
    t.print();

    // Batching benefit: per-frame time at b=8 vs b=1.
    let one = {
        let input = frames(1, 9);
        bench.run("zf b1", || {
            let _ = engine.infer("zf", 1, &input).unwrap();
        })
    };
    let eight = {
        let input = frames(8, 9);
        bench.run("zf b8", || {
            let _ = engine.infer("zf", 8, &input).unwrap();
        })
    };
    let speedup = one.mean_ms / (eight.mean_ms / 8.0);
    println!(
        "\ndynamic batching (zf): b1 {:.2} ms/frame vs b8 {:.2} ms/frame -> {speedup:.2}x",
        one.mean_ms,
        eight.mean_ms / 8.0
    );
    // On the CPU interpret path batching mostly amortizes dispatch (no MXU
    // to fill); it must at least stay within 2x of single-frame efficiency.
    // Real-TPU batching benefit is estimated statically (DESIGN.md §Perf).
    assert!(speedup > 0.5, "batched path pathologically slow: {speedup:.2}x");
    println!("bench_runtime OK");
}
