//! Ablation studies over the design choices DESIGN.md calls out:
//!   1. the 90% utilization headroom rule (what if 70%..100%?),
//!   2. arc-flow quantization granularity (cost/latency trade-off),
//!   3. the GCL candidate portfolio (exact-only vs +ARMVAC/NL incumbents).

use camflow::bench::{Bench, Table};
use camflow::cameras::scenarios;
use camflow::catalog::Catalog;
use camflow::coordinator::{Planner, PlannerConfig};
use camflow::packing::mcvbp::{solve, SolveOptions};

fn headroom_ablation() {
    println!("== Ablation 1: utilization headroom (paper: keep below 90%) ==");
    let catalog =
        Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
    let scn = scenarios::fig3_scenario1();
    let mut t = Table::new(&["headroom", "instances", "$/h", "peak util", "note"]);
    for headroom in [0.70, 0.80, 0.90, 0.95, 1.00] {
        let mut cfg = PlannerConfig::st3();
        cfg.headroom = headroom;
        match Planner::new(catalog.clone(), cfg).plan(&scn.requests) {
            Ok(plan) => {
                let peak = plan.packing.peak_utilization(&plan.problem);
                let note = if peak > 0.9 {
                    "degradation risk (>90%)"
                } else {
                    ""
                };
                t.row(&[
                    format!("{:.0}%", headroom * 100.0),
                    plan.instances.len().to_string(),
                    format!("{:.3}", plan.cost_per_hour),
                    format!("{:.0}%", peak * 100.0),
                    note.into(),
                ]);
            }
            Err(_) => t.row(&[
                format!("{:.0}%", headroom * 100.0),
                "-".into(),
                "infeasible".into(),
                "-".into(),
                "".into(),
            ]),
        }
    }
    t.print();
    println!("Tighter headroom never lowers cost; >90% buys nothing here but risks degradation.\n");
}

fn quantization_ablation() {
    println!("== Ablation 2: arc-flow quantization granularity ==");
    let catalog =
        Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
    let scn = scenarios::fig3_scenario3();
    let planner = Planner::new(catalog, PlannerConfig::st3());
    let (problem, _, _) = planner.build_problem(&scn.requests).unwrap();
    let bench = Bench::new(1, 5);
    let mut t = Table::new(&["grid", "exact $", "solve ms", "graph nodes", "milp vars"]);
    for quant in [15i64, 30, 60, 120] {
        let opts = SolveOptions { quant, ..Default::default() };
        let Ok((packing, stats)) = solve(&problem, &opts) else {
            t.row(&[quant.to_string(), "infeasible".into(), "-".into(), "-".into(), "-".into()]);
            continue;
        };
        let timing = bench.run("solve", || {
            let _ = solve(&problem, &opts);
        });
        t.row(&[
            quant.to_string(),
            format!("{:.3}", packing.total_cost(&problem)),
            format!("{:.1}", timing.mean_ms),
            stats.graph_nodes_after.to_string(),
            stats.milp_vars.to_string(),
        ]);
    }
    t.print();
    println!("Coarse grids are fast but overestimate demands (may cost more bins);\n60 cells/dim recovers the paper-exact Fig-3 packing.\n");
}

fn portfolio_ablation() {
    println!("== Ablation 3: GCL candidate portfolio ==");
    let catalog = Catalog::builtin();
    let mut t = Table::new(&["fps", "GCL raw $", "GCL portfolio $", "gain"]);
    for fps in [0.5, 2.0, 8.0, 20.0] {
        let requests = scenarios::fig6_workload(30, fps, 1);
        let raw = Planner::new(catalog.clone(), PlannerConfig::gcl())
            .plan_single(&requests)
            .map(|p| p.cost_per_hour);
        let portfolio = Planner::new(catalog.clone(), PlannerConfig::gcl())
            .plan(&requests)
            .map(|p| p.cost_per_hour);
        match (raw, portfolio) {
            (Ok(r), Ok(p)) => {
                assert!(p <= r + 1e-9);
                t.row(&[
                    fps.to_string(),
                    format!("{r:.3}"),
                    format!("{p:.3}"),
                    format!("{:.0}%", (1.0 - p / r) * 100.0),
                ]);
            }
            _ => t.row(&[fps.to_string(), "err".into(), "err".into(), "-".into()]),
        }
    }
    t.print();
    println!("The NL/ARMVAC incumbents matter exactly where the joint ILP exceeds the\nexact-phase budget and GCL would otherwise fall back to plain FFD.");
}

fn main() {
    headroom_ablation();
    quantization_ablation();
    portfolio_ablation();
    println!("\nbench_ablation OK");
}
