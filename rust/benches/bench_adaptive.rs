//! Adaptive-manager benchmarks (the runtime-adaptation experiment, [14]):
//!   * re-plan latency vs fleet size ("these methods can make resource
//!     decisions quickly and be applied during runtime"),
//!   * warm-start incremental re-plan vs cold re-plan on a ≤5%-perturbed
//!     workload (the staged pipeline's reuse path),
//!   * stream churn: sticky Expand vs the cold re-deal baseline on the same
//!     perturbed workload (`streams_moved` / churn ratio — every move is a
//!     reconnection and warm-state loss on the serving layer),
//!   * 24-hour rush-hour simulation: adaptive vs static-peak provisioning
//!     (the paper's ">50% cost reduction for real workloads" claim),
//!   * the unified portfolio runtime (scenarios in
//!     `camflow::bench::portfolio`): a forced winner flip on an unchanged
//!     workload must stay churn-free (`flip_churn_ratio` ≤ the sticky
//!     same-winner ratio + tolerance, zero provision/terminate), all three
//!     candidates must share one solve-worker pool (`pool_shared_jobs`),
//!     and the cross-candidate budget pool must fund the walled cluster
//!     (`budget_pooled_donated` > 0).
//!
//! Emits `BENCH_adaptive.json` so the perf + churn trajectory is tracked
//! across PRs.

use camflow::bench::{Bench, Table};
use camflow::cameras::{CameraDb, StreamRequest};
use camflow::catalog::Catalog;
use camflow::cloudsim::CloudSim;
use camflow::coordinator::pipeline::ReplanContext;
use camflow::coordinator::{adaptive::AdaptiveManager, Planner, PlannerConfig};
use camflow::profiles::Program;
use camflow::util::json::Value;
use std::time::Instant;

fn replan_latency(out: &mut Vec<Value>) {
    println!("== Re-plan latency vs fleet size (GCL, cold) ==");
    let catalog = Catalog::builtin();
    let bench = Bench::new(1, 5);
    let mut t = Table::new(&["cameras", "streams", "plan ms", "instances", "$/h"]);
    for &n in &[5usize, 10, 20, 50, 100, 200] {
        let db = CameraDb::synthetic(n, 11);
        let requests = db.workload(Program::Zf, 1.0);
        let planner = Planner::new(catalog.clone(), PlannerConfig::gcl());
        let timing = bench.run(&format!("plan {n}"), || {
            let _ = planner.plan(&requests).unwrap();
        });
        let plan = planner.plan(&requests).unwrap();
        t.row(&[
            n.to_string(),
            requests.len().to_string(),
            format!("{:.1}", timing.mean_ms),
            plan.instances.len().to_string(),
            format!("{:.3}", plan.cost_per_hour),
        ]);
        out.push(Value::obj(vec![
            ("cameras", Value::num(n as f64)),
            ("streams", Value::num(requests.len() as f64)),
            ("cold_plan_ms", Value::num(timing.mean_ms)),
            ("instances", Value::num(plan.instances.len() as f64)),
            ("usd_per_hour", Value::num(plan.cost_per_hour)),
        ]));
        // "Quickly applied during runtime": stay well under a second at
        // paper scale (tens of cameras), a few seconds at hundreds. Like
        // the warm-speedup bar, this is wall-clock — recorded but not
        // asserted under BENCH_LENIENT_TIMING (shared CI runners).
        if n <= 50 && timing.mean_ms >= 1_000.0 {
            let msg = format!("plan too slow at {n} cams: {timing}");
            assert!(std::env::var_os("BENCH_LENIENT_TIMING").is_some(), "{msg}");
            println!("WARNING (not asserted, BENCH_LENIENT_TIMING set): {msg}");
        }
    }
    t.print();
}

/// Perturb ≤5% of the requests: every 20th stream doubles its rate.
fn perturb(base: &[StreamRequest]) -> Vec<StreamRequest> {
    base.iter()
        .enumerate()
        .map(|(i, r)| {
            if i % 20 == 0 {
                StreamRequest::new(r.camera.clone(), r.program, r.desired_fps * 2.0)
            } else {
                r.clone()
            }
        })
        .collect()
}

fn warm_vs_cold(out: &mut Vec<Value>) {
    println!("\n== Warm incremental vs cold re-plan, ≤5% perturbed workload (GCL) ==");
    let catalog = Catalog::builtin();
    let mut t = Table::new(&[
        "streams", "cold ms", "warm ms", "speedup", "cold $/h", "warm $/h", "reuse",
    ]);
    let rounds = 5usize;
    let mut largest_speedup = 0.0f64;
    let mut largest_cold_ms = 0.0f64;
    for &n in &[50usize, 200, 1000] {
        let db = CameraDb::synthetic(n, 11);
        let base = db.workload(Program::Zf, 1.0);
        let perturbed = perturb(&base);
        let planner = Planner::new(catalog.clone(), PlannerConfig::gcl());

        // Cold: plan the perturbed workload from scratch.
        let mut cold_ms = 0.0;
        let mut cold_cost = 0.0;
        for _ in 0..rounds {
            let t0 = Instant::now();
            let plan = planner.plan(&perturbed).unwrap();
            cold_ms += t0.elapsed().as_secs_f64() * 1000.0;
            cold_cost = plan.cost_per_hour;
        }
        cold_ms /= rounds as f64;

        // Warm: prime the context with the base workload (untimed), then
        // re-plan the perturbation through the persistent context.
        let mut warm_ms = 0.0;
        let mut warm_cost = 0.0;
        let mut reuse = 0.0;
        for _ in 0..rounds {
            let mut ctx = ReplanContext::new();
            planner.plan_with(&base, &mut ctx).unwrap();
            let t0 = Instant::now();
            let plan = planner.plan_with(&perturbed, &mut ctx).unwrap();
            warm_ms += t0.elapsed().as_secs_f64() * 1000.0;
            warm_cost = plan.cost_per_hour;
            reuse = plan.pipeline.reuse_ratio();
        }
        warm_ms /= rounds as f64;

        let speedup = cold_ms / warm_ms.max(1e-9);
        t.row(&[
            base.len().to_string(),
            format!("{cold_ms:.1}"),
            format!("{warm_ms:.1}"),
            format!("{speedup:.1}x"),
            format!("{cold_cost:.3}"),
            format!("{warm_cost:.3}"),
            format!("{:.0}%", reuse * 100.0),
        ]);
        out.push(Value::obj(vec![
            ("streams", Value::num(base.len() as f64)),
            ("cold_ms", Value::num(cold_ms)),
            ("warm_ms", Value::num(warm_ms)),
            ("speedup", Value::num(speedup)),
            ("cold_usd_per_hour", Value::num(cold_cost)),
            ("warm_usd_per_hour", Value::num(warm_cost)),
            ("reuse_ratio", Value::num(reuse)),
        ]));

        // At budget-bound scales the exact phase can fall back to heuristics,
        // where the warm incumbent legitimately *beats* the cold plan; the
        // invariant is therefore warm <= cold. Bit-equality is asserted on
        // the paper-scale Fig 6 scenarios below, where exact solves complete.
        assert!(
            warm_cost <= cold_cost + 1e-6,
            "warm re-plan cost {warm_cost} worse than cold {cold_cost} at {n} cameras"
        );
        largest_speedup = speedup;
        largest_cold_ms = cold_ms;
    }
    t.print();
    // The acceptance bar: on the largest workload, where solve time dominates
    // fixed overheads, the incremental re-plan must be at least 2x faster.
    // Wall-clock ratios are noisy on shared CI runners, so CI sets
    // BENCH_LENIENT_TIMING=1 to record the ratio without gating on it; the
    // churn and cost bars stay asserted everywhere (they're deterministic).
    let lenient = std::env::var_os("BENCH_LENIENT_TIMING").is_some();
    if largest_cold_ms >= 5.0 && largest_speedup < 2.0 {
        let msg =
            format!("warm re-plan speedup {largest_speedup:.2}x < 2x at the largest size");
        assert!(lenient, "{msg}");
        println!("WARNING (not asserted, BENCH_LENIENT_TIMING set): {msg}");
    }
}

/// Stream churn on the ≤5%-perturbed workload: the sticky Expand keeps
/// every stream on its previous slot when the new packing has room, so
/// `streams_moved` tracks the packing diff; the cold re-deal baseline
/// (PR-1 behaviour) re-deals streams in queue order every re-plan.
fn churn_tracking(out: &mut Vec<Value>) {
    println!("\n== Stream churn: sticky Expand vs cold re-deal, ≤5% perturbed (GCL) ==");
    let catalog = Catalog::builtin();
    let mut t = Table::new(&[
        "streams",
        "redeal moved",
        "sticky moved",
        "sticky churn",
        "redeal $/h",
        "sticky $/h",
        "repeat moved",
    ]);
    let mut total_redeal = 0usize;
    let mut total_sticky = 0usize;
    for &n in &[50usize, 200, 1000] {
        let db = CameraDb::synthetic(n, 11);
        let base = db.workload(Program::Zf, 1.0);
        let perturbed = perturb(&base);
        let planner = Planner::new(catalog.clone(), PlannerConfig::gcl());

        let mut sticky_mgr = AdaptiveManager::new(planner.clone());
        sticky_mgr.replan(base.clone()).unwrap();
        let sticky = sticky_mgr.replan(perturbed.clone()).unwrap();
        // Identical consecutive workloads must not move anything at all.
        let repeat = sticky_mgr.replan(perturbed.clone()).unwrap();
        assert_eq!(
            repeat.streams_moved, 0,
            "identical consecutive re-plan moved {} streams",
            repeat.streams_moved
        );

        let mut redeal_mgr = AdaptiveManager::cold(planner);
        redeal_mgr.replan(base.clone()).unwrap();
        let redeal = redeal_mgr.replan(perturbed).unwrap();

        // Stickiness is free: plan quality never regresses for it.
        assert!(
            sticky.cost_after <= redeal.cost_after + 1e-6,
            "sticky re-plan cost {} worse than re-deal {} at {n} cameras",
            sticky.cost_after,
            redeal.cost_after
        );
        total_redeal += redeal.streams_moved;
        total_sticky += sticky.streams_moved;

        t.row(&[
            base.len().to_string(),
            redeal.streams_moved.to_string(),
            sticky.streams_moved.to_string(),
            format!("{:.1}%", sticky.churn_ratio() * 100.0),
            format!("{:.3}", redeal.cost_after),
            format!("{:.3}", sticky.cost_after),
            repeat.streams_moved.to_string(),
        ]);
        out.push(Value::obj(vec![
            ("streams", Value::num(base.len() as f64)),
            ("redeal_moved", Value::num(redeal.streams_moved as f64)),
            ("sticky_moved", Value::num(sticky.streams_moved as f64)),
            ("redeal_churn_ratio", Value::num(redeal.churn_ratio())),
            ("sticky_churn_ratio", Value::num(sticky.churn_ratio())),
            ("redeal_usd_per_hour", Value::num(redeal.cost_after)),
            ("sticky_usd_per_hour", Value::num(sticky.cost_after)),
            ("repeat_moved", Value::num(repeat.streams_moved as f64)),
        ]));
    }
    t.print();
    // The acceptance bar: across the perturbed workloads, sticky Expand
    // must move strictly fewer streams than the re-deal baseline (unless
    // the baseline already moved nothing — then sticky must too).
    assert!(
        total_redeal == 0 || total_sticky < total_redeal,
        "sticky Expand did not reduce churn: sticky {total_sticky} vs re-deal {total_redeal}"
    );
    println!("churn: sticky {total_sticky} moved vs re-deal {total_redeal} moved");
}

fn fig6_warm_cost_parity(out: &mut Vec<Value>) {
    println!("\n== Fig 6 scenarios: warm re-plan cost == cold cost ==");
    use camflow::cameras::scenarios;
    let catalog = Catalog::builtin();
    let mut checked = 0usize;
    for fps in [0.5, 2.0, 8.0] {
        let requests = scenarios::fig6_workload(24, fps, 5);
        let planner = Planner::new(catalog.clone(), PlannerConfig::gcl());
        let cold = planner.plan(&requests).unwrap();
        let mut ctx = ReplanContext::new();
        planner.plan_with(&requests, &mut ctx).unwrap();
        let warm = planner.plan_with(&requests, &mut ctx).unwrap();
        assert!(
            (warm.cost_per_hour - cold.cost_per_hour).abs() < 1e-9,
            "fig6 fps={fps}: warm {} != cold {}",
            warm.cost_per_hour,
            cold.cost_per_hour
        );
        checked += 1;
        out.push(Value::obj(vec![
            ("fps", Value::num(fps)),
            ("cold_usd_per_hour", Value::num(cold.cost_per_hour)),
            ("warm_usd_per_hour", Value::num(warm.cost_per_hour)),
        ]));
    }
    println!("cost parity holds on {checked} Fig 6 workloads");
}

fn day_simulation(out: &mut Vec<(&'static str, Value)>) {
    println!("\n== 24 h adaptive vs static-peak provisioning ==");
    let catalog = Catalog::builtin();
    let planner = Planner::new(catalog.clone(), PlannerConfig::gcl());
    let mut mgr = AdaptiveManager::new(planner);
    let mut sim = CloudSim::new(catalog);
    let db = CameraDb::synthetic(12, 3);

    let mut peak = 0.0f64;
    let mut moved_total = 0usize;
    let mut surviving_total = 0usize;
    let t0 = Instant::now();
    for h in 0..24 {
        let fps = match h % 24 {
            7..=9 | 16..=18 => 8.0,
            22 | 23 | 0..=5 => 0.2,
            _ => 1.0,
        };
        let report = mgr.replan(db.workload(Program::Zf, fps)).unwrap();
        moved_total += report.streams_moved;
        surviving_total += report.streams_surviving;
        let plan = mgr.current_plan().unwrap();
        sim.apply_plan(plan).unwrap();
        sim.advance(3600.0);
        peak = peak.max(plan.cost_per_hour);
    }
    let day_ms = t0.elapsed().as_secs_f64() * 1000.0;
    let adaptive = sim.accrued_usd();
    let static_peak = peak * 24.0;
    let saving = 1.0 - adaptive / static_peak;
    println!(
        "adaptive: ${adaptive:.2}  static-peak: ${static_peak:.2}  saving: {:.0}%  streams moved: {moved_total}  ({day_ms:.0} ms for 24 warm re-plans)",
        saving * 100.0
    );
    assert!(saving > 0.5, "paper claims >50% cost reduction for real (varying) workloads");
    let day_churn = if surviving_total == 0 {
        0.0
    } else {
        moved_total as f64 / surviving_total as f64
    };
    out.push((
        "day_simulation",
        Value::obj(vec![
            ("adaptive_usd", Value::num(adaptive)),
            ("static_peak_usd", Value::num(static_peak)),
            ("saving", Value::num(saving)),
            ("streams_moved", Value::num(moved_total as f64)),
            ("streams_surviving", Value::num(surviving_total as f64)),
            ("churn_ratio", Value::num(day_churn)),
            ("total_replan_ms", Value::num(day_ms)),
        ]),
    ));
}

/// The unified portfolio runtime: winner-flip continuity + shared
/// solve-pool/budget-pool measurements. The scenarios live in the library
/// (`camflow::bench::portfolio`) so the integration suite schema-checks the
/// very same fields this section writes.
fn portfolio_runtime(out: &mut Vec<(&'static str, Value)>) {
    println!("\n== Unified portfolio runtime: winner-flip churn + shared pools ==");
    let o = camflow::bench::portfolio::run();
    // The acceptance bar: a forced winner flip on an unchanged workload
    // must not churn more than the sticky same-winner control re-plan.
    assert!(
        o.flip_churn_ratio <= o.sticky_churn_ratio + 0.05,
        "winner flip churned the fleet: flip {} vs sticky {}",
        o.flip_churn_ratio,
        o.sticky_churn_ratio
    );
    assert_eq!(
        (o.flip_provisioned, o.flip_terminated),
        (0, 0),
        "forced flip on an unchanged workload must not touch the fleet"
    );
    assert!(o.winner_flips >= 1, "scenario must actually flip the winner");
    assert!(o.pool_shared_jobs > 0, "candidates must solve on the shared pool");
    assert!(o.budget_pooled_donated > 0, "cross-candidate pool must engage");
    println!(
        "flip churn {:.1}%  sticky churn {:.1}%  flips {}  pool jobs {}  pooled nodes {}",
        o.flip_churn_ratio * 100.0,
        o.sticky_churn_ratio * 100.0,
        o.winner_flips,
        o.pool_shared_jobs,
        o.budget_pooled_donated
    );
    out.push(("portfolio", o.to_json()));
}

fn main() {
    // BENCH_PORTFOLIO_ONLY=1 runs just the portfolio section and writes a
    // BENCH_adaptive.json holding only it — the `scale` CI lane uses this
    // to gate/upload the winner-flip bars without re-running the latency/
    // churn/day sections the `rust` lane already executed.
    let portfolio_only = std::env::var_os("BENCH_PORTFOLIO_ONLY").is_some();
    let mut latency = Vec::new();
    let mut warm = Vec::new();
    let mut churn = Vec::new();
    let mut fig6 = Vec::new();
    let mut extra = Vec::new();

    if !portfolio_only {
        replan_latency(&mut latency);
        warm_vs_cold(&mut warm);
        churn_tracking(&mut churn);
        fig6_warm_cost_parity(&mut fig6);
        day_simulation(&mut extra);
    }
    portfolio_runtime(&mut extra);

    let mut pairs = vec![("bench", Value::str("adaptive"))];
    if !portfolio_only {
        pairs.push(("replan_latency", Value::arr(latency)));
        pairs.push(("warm_vs_cold", Value::arr(warm)));
        pairs.push(("churn", Value::arr(churn)));
        pairs.push(("fig6_cost_parity", Value::arr(fig6)));
    }
    pairs.extend(extra);
    let doc = Value::obj(pairs);
    let path = "BENCH_adaptive.json";
    std::fs::write(path, camflow::util::json::to_string_pretty(&doc))
        .expect("write BENCH_adaptive.json");
    println!("\nwrote {path}");
    println!("\nbench_adaptive OK");
}
