//! Adaptive-manager benchmarks (the runtime-adaptation experiment, [14]):
//!   * re-plan latency vs fleet size ("these methods can make resource
//!     decisions quickly and be applied during runtime"),
//!   * 24-hour rush-hour simulation: adaptive vs static-peak provisioning
//!     (the paper's ">50% cost reduction for real workloads" claim).

use camflow::bench::{Bench, Table};
use camflow::cameras::CameraDb;
use camflow::catalog::Catalog;
use camflow::cloudsim::CloudSim;
use camflow::coordinator::{adaptive::AdaptiveManager, Planner, PlannerConfig};
use camflow::profiles::Program;

fn replan_latency() {
    println!("== Re-plan latency vs fleet size (GCL) ==");
    let catalog = Catalog::builtin();
    let bench = Bench::new(1, 5);
    let mut t = Table::new(&["cameras", "streams", "plan ms", "instances", "$/h"]);
    for &n in &[5usize, 10, 20, 50, 100, 200] {
        let db = CameraDb::synthetic(n, 11);
        let requests = db.workload(Program::Zf, 1.0);
        let planner = Planner::new(catalog.clone(), PlannerConfig::gcl());
        let timing = bench.run(&format!("plan {n}"), || {
            let _ = planner.plan(&requests).unwrap();
        });
        let plan = planner.plan(&requests).unwrap();
        t.row(&[
            n.to_string(),
            requests.len().to_string(),
            format!("{:.1}", timing.mean_ms),
            plan.instances.len().to_string(),
            format!("{:.3}", plan.cost_per_hour),
        ]);
        // "Quickly applied during runtime": stay well under a second at
        // paper scale (tens of cameras), a few seconds at hundreds.
        if n <= 50 {
            assert!(timing.mean_ms < 1_000.0, "plan too slow at {n} cams: {timing}");
        }
    }
    t.print();
}

fn day_simulation() {
    println!("\n== 24 h adaptive vs static-peak provisioning ==");
    let catalog = Catalog::builtin();
    let planner = Planner::new(catalog.clone(), PlannerConfig::gcl());
    let mut mgr = AdaptiveManager::new(planner);
    let mut sim = CloudSim::new(catalog);
    let db = CameraDb::synthetic(12, 3);

    let mut peak = 0.0f64;
    let mut moved_total = 0usize;
    for h in 0..24 {
        let fps = match h % 24 {
            7..=9 | 16..=18 => 8.0,
            22 | 23 | 0..=5 => 0.2,
            _ => 1.0,
        };
        let report = mgr.replan(db.workload(Program::Zf, fps)).unwrap();
        moved_total += report.streams_moved;
        let plan = mgr.current_plan().unwrap();
        sim.apply_plan(plan).unwrap();
        sim.advance(3600.0);
        peak = peak.max(plan.cost_per_hour);
    }
    let adaptive = sim.accrued_usd();
    let static_peak = peak * 24.0;
    let saving = 1.0 - adaptive / static_peak;
    println!(
        "adaptive: ${adaptive:.2}  static-peak: ${static_peak:.2}  saving: {:.0}%  streams moved: {moved_total}",
        saving * 100.0
    );
    assert!(saving > 0.5, "paper claims >50% cost reduction for real (varying) workloads");
}

fn main() {
    replan_latency();
    day_simulation();
    println!("\nbench_adaptive OK");
}
