"""L2 — JAX analysis programs (compact VGG16-style and ZF-style detectors).

The paper analyzes camera streams with two object-detection programs, VGG16
[Simonyan & Zisserman] and ZF [Zeiler & Fergus]. We build compact versions of
both (64x64x3 input, single-scale detection head) whose every conv / dense layer
routes through the L1 Pallas matmul kernel via im2col, so the whole network
lowers into one HLO module containing the kernel.

Design notes:
  * Parameters are *inputs* of the lowered function (not baked constants) —
    they are exported once to ``<name>.params.bin`` and fed by the Rust runtime
    at session load. This keeps the HLO text small and lets one artifact serve
    any weight set.
  * ``im2col`` is written as a static stack of shifted slices so the patch
    ordering exactly matches a row-major reshape of HWIO weights — no
    layout-fixup transposes in the lowered module (see DESIGN.md "Perf" L2).
  * Detection head: 1x1 conv -> A*(5+C) channels over the final grid, reshaped
    to (N, cells*A, 5+C): [tx, ty, tw, th, objectness, class logits...].
"""

from __future__ import annotations

import functools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul

# Detection head geometry (shared by both programs).
NUM_ANCHORS = 2
NUM_CLASSES = 4  # person, vehicle, cyclist, other — the CAM2 tracking classes
HEAD_CH = NUM_ANCHORS * (5 + NUM_CLASSES)

INPUT_SIZE = 64  # HxW of the analysis frame fed to either program


# ---------------------------------------------------------------------------
# Layers (all matmuls go through the Pallas kernel)
# ---------------------------------------------------------------------------

def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int, same: bool) -> jnp.ndarray:
    """NHWC -> (N, Ho, Wo, kh*kw*C) patch tensor with static slicing.

    Patch ordering is (di, dj, c) row-major, matching ``w.reshape(kh*kw*C, O)``
    for HWIO weights.
    """
    n, h, w_, c = x.shape
    if same:
        # SAME padding; clamp at 0 (kernels smaller than the stride need none).
        ph = max(0, ((h - 1) // stride) * stride + kh - h)
        pw = max(0, ((w_ - 1) // stride) * stride + kw - w_)
        pt, pb = ph // 2, ph - ph // 2
        pl_, pr = pw // 2, pw - pw // 2
        x = jnp.pad(x, ((0, 0), (pt, pb), (pl_, pr), (0, 0)))
        h, w_ = h + ph, w_ + pw
    ho = (h - kh) // stride + 1
    wo = (w_ - kw) // stride + 1
    cols = []
    for di in range(kh):
        for dj in range(kw):
            cols.append(
                x[:, di : di + stride * ho : stride, dj : dj + stride * wo : stride, :]
            )
    return jnp.concatenate(cols, axis=-1)


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    *,
    stride: int = 1,
    same: bool = True,
    relu: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """NHWC conv through im2col + the Pallas matmul kernel. w is HWIO."""
    kh, kw, cin, cout = w.shape
    cols = im2col(x, kh, kw, stride, same)
    n, ho, wo, k = cols.shape
    flat = cols.reshape(n * ho * wo, k)
    out = matmul(flat, w.reshape(kh * kw * cin, cout), b, relu=relu, interpret=interpret)
    return out.reshape(n, ho, wo, cout)


def maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2/2 max pool via reshape (H, W must be even)."""
    n, h, w, c = x.shape
    return x.reshape(n, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def dense(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *, relu: bool, interpret: bool = True
) -> jnp.ndarray:
    return matmul(x, w, b, relu=relu, interpret=interpret)


# ---------------------------------------------------------------------------
# Architectures
# ---------------------------------------------------------------------------
# Spec entries: ("conv", kh, kw, cout, stride) | ("pool",) — ReLU after every conv.
# The final entry is always the linear 1x1 detection head (added automatically).

ARCHS: Dict[str, List[tuple]] = {
    # Compact VGG16: 3x3 conv stacks + 2x2 pools, 64 -> 8 spatial.
    "vgg16": [
        ("conv", 3, 3, 8, 1),
        ("conv", 3, 3, 8, 1),
        ("pool",),
        ("conv", 3, 3, 16, 1),
        ("conv", 3, 3, 16, 1),
        ("pool",),
        ("conv", 3, 3, 32, 1),
        ("conv", 3, 3, 32, 1),
        ("pool",),
    ],
    # Compact ZF: large stride-2 first filter, then 3x3 stacks. 64 -> 8 spatial.
    "zf": [
        ("conv", 7, 7, 8, 2),
        ("pool",),
        ("conv", 3, 3, 16, 1),
        ("conv", 3, 3, 32, 1),
        ("pool",),
    ],
}


def _he_init(key, shape) -> jnp.ndarray:
    fan_in = 1
    for d in shape[:-1]:
        fan_in *= d
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def init_params(arch: str, seed: int = 0) -> List[jnp.ndarray]:
    """Deterministic parameter list [w0, b0, w1, b1, ..., w_head, b_head]."""
    if arch not in ARCHS:
        raise ValueError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")
    key = jax.random.PRNGKey(seed)
    params: List[jnp.ndarray] = []
    cin = 3
    for spec in ARCHS[arch]:
        if spec[0] == "pool":
            continue
        _, kh, kw, cout, _ = spec
        key, k1 = jax.random.split(key)
        params.append(_he_init(k1, (kh, kw, cin, cout)))
        params.append(jnp.zeros((cout,), jnp.float32))
        cin = cout
    key, k1 = jax.random.split(key)
    params.append(_he_init(k1, (1, 1, cin, HEAD_CH)))
    params.append(jnp.zeros((HEAD_CH,), jnp.float32))
    return params


def param_shapes(arch: str) -> List[Tuple[int, ...]]:
    return [tuple(p.shape) for p in init_params(arch)]


def forward(
    arch: str, params: Sequence[jnp.ndarray], x: jnp.ndarray, *, interpret: bool = True
) -> jnp.ndarray:
    """Run the detector. x: (N, 64, 64, 3) f32 in [0,1].

    Returns detections (N, cells*A, 5+C) raw (logits, un-decoded boxes).
    """
    i = 0
    for spec in ARCHS[arch]:
        if spec[0] == "pool":
            x = maxpool2(x)
            continue
        _, _, _, _, stride = spec
        x = conv2d(x, params[i], params[i + 1], stride=stride, relu=True, interpret=interpret)
        i += 2
    # Detection head: 1x1 conv, linear.
    x = conv2d(x, params[i], params[i + 1], stride=1, relu=False, interpret=interpret)
    n, h, w, _ = x.shape
    return x.reshape(n, h * w * NUM_ANCHORS, 5 + NUM_CLASSES)


def output_shape(arch: str, batch: int) -> Tuple[int, int, int]:
    dummy_cells = {"vgg16": 8 * 8, "zf": 8 * 8}[arch]
    return (batch, dummy_cells * NUM_ANCHORS, 5 + NUM_CLASSES)


def flops_per_frame(arch: str) -> int:
    """MACs*2 of all convs + head for one 64x64 frame (analytic)."""
    h = w = INPUT_SIZE
    cin = 3
    total = 0
    for spec in ARCHS[arch]:
        if spec[0] == "pool":
            h //= 2
            w //= 2
            continue
        _, kh, kw, cout, stride = spec
        ho, wo = h // stride, w // stride
        total += 2 * ho * wo * kh * kw * cin * cout
        h, w, cin = ho, wo, cout
    total += 2 * h * w * cin * HEAD_CH
    return total


def make_jit(arch: str, batch: int):
    """A jitted closure (params..., x) -> detections, plus its arg specs."""
    nparams = len(param_shapes(arch))

    @functools.partial(jax.jit)
    def fn(*args):
        params, x = args[:nparams], args[nparams]
        return (forward(arch, params, x),)

    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in param_shapes(arch)]
    specs.append(jax.ShapeDtypeStruct((batch, INPUT_SIZE, INPUT_SIZE, 3), jnp.float32))
    return fn, specs
