"""AOT compile path: lower every (analysis program, batch size) variant to HLO
*text* and export parameters + a manifest for the Rust runtime.

HLO text (NOT ``lowered.compiler_ir(...).serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts [--models vgg16,zf]
                              [--batches 1,4,8] [--seed 0]
"""

from __future__ import annotations

import argparse
import json
import os
from typing import List

import numpy as np

from . import model as M


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_params(arch: str, seed: int, out_dir: str) -> str:
    """Concatenate all parameters (row-major f32 LE) into <arch>.params.bin."""
    params = M.init_params(arch, seed)
    path = os.path.join(out_dir, f"{arch}.params.bin")
    with open(path, "wb") as f:
        for p in params:
            f.write(np.asarray(p, dtype="<f4").tobytes())
    return os.path.basename(path)


def lower_model(arch: str, batch: int, out_dir: str) -> dict:
    import jax

    fn, specs = M.make_jit(arch, batch)
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    fname = f"{arch}_b{batch}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    out_shape = M.output_shape(arch, batch)
    return {
        "name": arch,
        "batch": batch,
        "hlo": fname,
        "params_bin": f"{arch}.params.bin",
        "param_shapes": [list(s) for s in M.param_shapes(arch)],
        "input_shape": [batch, M.INPUT_SIZE, M.INPUT_SIZE, 3],
        "output_shape": list(out_shape),
        "flops_per_frame": M.flops_per_frame(arch),
        "hlo_chars": len(text),
    }


def main(argv: List[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="vgg16,zf")
    ap.add_argument("--batches", default="1,4,8")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    models = [m.strip() for m in args.models.split(",") if m.strip()]
    batches = [int(b) for b in args.batches.split(",") if b.strip()]

    entries = []
    for arch in models:
        export_params(arch, args.seed, args.out_dir)
        for batch in batches:
            entry = lower_model(arch, batch, args.out_dir)
            entries.append(entry)
            print(
                f"lowered {arch} b{batch}: {entry['hlo_chars']} chars, "
                f"{entry['flops_per_frame'] / 1e6:.1f} MFLOP/frame"
            )

    manifest = {
        "version": 1,
        "input_size": M.INPUT_SIZE,
        "num_classes": M.NUM_CLASSES,
        "num_anchors": M.NUM_ANCHORS,
        "seed": args.seed,
        "models": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(entries)} model variants to {args.out_dir}")


if __name__ == "__main__":
    main()
