"""camflow compile path — L2 JAX models + L1 Pallas kernels, AOT-lowered to HLO
text consumed by the Rust PJRT runtime. Build-time only; never on the request
path."""
