"""L1 — Pallas tiled matmul kernel (the compute hot-spot of every analysis program).

The paper's analysis programs (VGG16 / ZF object detectors) spend essentially all
of their time in convolutions, which we lower as im2col + matmul. This module
implements that matmul as a single Pallas kernel with a fused bias+ReLU epilogue,
tiled for the TPU MXU (128x128 systolic array).

Hardware adaptation (paper ran CUDA/Caffe on EC2 GPUs):
  * threadblock K-loop + shared-memory staging  ->  grid K dimension + VMEM
    BlockSpec tiles (the accumulator lives in the output ref across K steps),
  * warp epilogue fusion                        ->  bias+ReLU on the final K step,
  * tensor-core WMMA tiles                      ->  MXU-shaped blocks (multiples
    of (8, 128) for f32).

Kernels are lowered with ``interpret=True`` (the CPU PJRT plugin cannot execute
Mosaic custom-calls); real-TPU performance is estimated from the VMEM footprint
and MXU utilization of the chosen block shapes (see ``vmem_bytes`` /
``mxu_utilization`` and DESIGN.md section "Perf").
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly defaults: f32 operands tile as (8, 128) in VMEM; 128x128 blocks
# keep the systolic array fully fed while 3 tiles x 64KiB stays far below VMEM.
DEFAULT_BM = 128
DEFAULT_BK = 128
DEFAULT_BN = 128

# TPU v4-class VMEM budget per core (bytes). Used only for static estimates.
VMEM_BUDGET = 16 * 1024 * 1024


def _mm_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, relu: bool, has_bias: bool):
    """Grid = (M/bm, N/bn, K/bk); K innermost so o_ref accumulates in VMEM."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...]
        if has_bias:
            acc = acc + b_ref[...]
        if relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


def _pick_block(dim: int, pref: int) -> int:
    """Largest power-of-two block <= pref that keeps padding overhead sane."""
    b = pref
    while b > 8 and b > dim:
        b //= 2
    return max(b, 8)


def _pick_bm(m: int, bk: int, bn: int) -> int:
    """Row-block size: grow with M (fewer grid steps) within the VMEM budget.

    Perf note (EXPERIMENTS.md §Perf/L1): every grid step materializes the
    output block, so tiny row blocks make the M-loop overhead quadratic in M
    on the interpret/CPU path and waste prefetch bandwidth on TPU. Growing bm
    until the working set nears half of VMEM cut end-to-end inference time
    ~3-8x at batch 8 while keeping (8, 128)-aligned MXU tiles.
    """
    bm = _pick_block(m, DEFAULT_BM)
    while bm < 8192 and bm < m and vmem_bytes(bm * 2, bk, bn) <= VMEM_BUDGET // 2:
        bm *= 2
    return bm


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    relu: bool = False,
    bm: Optional[int] = None,
    bk: Optional[int] = None,
    bn: Optional[int] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """``maximum(x @ w + b, 0)`` (bias/ReLU optional) via the Pallas kernel.

    Shapes: x (M, K), w (K, N), b (N,) or (1, N). Arbitrary M/K/N — inputs are
    zero-padded up to block multiples and the result is sliced back.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul expects rank-2 operands, got {x.shape} @ {w.shape}")
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"inner dims mismatch: {x.shape} @ {w.shape}")

    bk = bk or _pick_block(K, DEFAULT_BK)
    bn = bn or _pick_block(N, DEFAULT_BN)
    bm = bm or _pick_bm(M, bk, bn)

    xp = _pad_to(_pad_to(x.astype(jnp.float32), 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), 0, bk), 1, bn)
    has_bias = b is not None
    if has_bias:
        bb = jnp.asarray(b, jnp.float32).reshape(1, -1)
        if bb.shape[1] != N:
            raise ValueError(f"bias shape {b.shape} incompatible with N={N}")
        bp = _pad_to(bb, 1, bn)
    else:
        bp = jnp.zeros((1, bn), jnp.float32)

    Mp, Kp = xp.shape
    _, Np = wp.shape
    nk = Kp // bk

    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk, relu=relu, has_bias=has_bias),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# Static TPU performance estimates (structure-level; interpret-mode wallclock
# is CPU-numpy time and NOT a TPU proxy — see DESIGN.md "Perf").
# ---------------------------------------------------------------------------

def vmem_bytes(bm: int, bk: int, bn: int, dtype_bytes: int = 4) -> int:
    """Resident VMEM per grid step: x tile + w tile + bias tile + out/acc tile.

    Double-buffered inputs (Pallas prefetches the next block while computing)
    double the x/w/bias contribution.
    """
    x_tile = bm * bk * dtype_bytes
    w_tile = bk * bn * dtype_bytes
    b_tile = bn * dtype_bytes
    o_tile = bm * bn * 4  # accumulator is always f32
    return 2 * (x_tile + w_tile + b_tile) + o_tile


def mxu_utilization(bm: int, bk: int, bn: int) -> float:
    """Fraction of MXU issue slots used by one (bm, bk, bn) block product.

    The 128x128 MXU retires one 128x128x8 f32 MACC block per 8 cycles
    (f32 runs at 1/8 the bf16 rate through pass-through mode); a block that is
    not a multiple of the native tile wastes the remainder lanes.
    """
    eff = (bm * bn * bk)
    padded = (
        -(-bm // 128) * 128 * -(-bn // 128) * 128 * -(-bk // 8) * 8
    )
    return eff / padded


def block_report(bm: int, bk: int, bn: int) -> dict:
    """Summary dict used by tests and the perf log."""
    vb = vmem_bytes(bm, bk, bn)
    return {
        "bm": bm,
        "bk": bk,
        "bn": bn,
        "vmem_bytes": vb,
        "vmem_frac": vb / VMEM_BUDGET,
        "fits_vmem": vb <= VMEM_BUDGET,
        "mxu_utilization": mxu_utilization(bm, bk, bn),
    }
