"""Pure-jnp oracles for the Pallas kernels and the model layers.

This is the correctness ground truth: every kernel and every composite layer in
``model.py`` is pytest-checked against these reference implementations at build
time (before any artifact ships to the Rust runtime).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
from jax import lax


def matmul_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    relu: bool = False,
) -> jnp.ndarray:
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        out = out + jnp.asarray(b, jnp.float32).reshape(1, -1)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def conv2d_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    relu: bool = False,
) -> jnp.ndarray:
    """NHWC x HWIO -> NHWC convolution via lax.conv_general_dilated."""
    out = lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        out = out + jnp.asarray(b, jnp.float32).reshape(1, 1, 1, -1)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def maxpool_ref(x: jnp.ndarray, window: int = 2, stride: int = 2) -> jnp.ndarray:
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )


def softmax_ref(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    x = x - jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x)
    return e / jnp.sum(e, axis=axis, keepdims=True)
