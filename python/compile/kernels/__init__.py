"""L1 Pallas kernels + pure-jnp reference oracles."""

from . import ref  # noqa: F401
from .matmul import (  # noqa: F401
    matmul,
    vmem_bytes,
    mxu_utilization,
    block_report,
    VMEM_BUDGET,
    DEFAULT_BM,
    DEFAULT_BK,
    DEFAULT_BN,
)
