"""L2 correctness: composite layers vs lax references; model shape contracts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels.ref import conv2d_ref, maxpool_ref

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(1, 2),
    hw=st.sampled_from([8, 12, 16]),
    cin=st.integers(1, 4),
    cout=st.integers(1, 6),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    relu=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_conv2d_matches_lax(n, hw, cin, cout, k, stride, relu, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, hw, hw, cin)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, k, cin, cout)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((cout,)), jnp.float32)
    got = M.conv2d(x, w, b, stride=stride, relu=relu)
    want = conv2d_ref(x, w, b, stride=stride, padding="SAME", relu=relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_conv2d_stride2_even_kernel_7x7():
    # The ZF first layer: 7x7 stride-2 on 64x64.
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 64, 64, 3)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((7, 7, 3, 8)), jnp.float32)
    b = jnp.zeros((8,))
    got = M.conv2d(x, w, b, stride=2, relu=True)
    want = conv2d_ref(x, w, b, stride=2, padding="SAME", relu=True)
    assert got.shape == (1, 32, 32, 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_maxpool2_matches_lax():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 16, 16, 5)), jnp.float32)
    got = M.maxpool2(x)
    want = maxpool_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_im2col_ordering_matches_hwio_reshape():
    # conv via explicit im2col must equal the lax conv for a delta filter.
    x = jnp.arange(2 * 6 * 6 * 2, dtype=jnp.float32).reshape(2, 6, 6, 2)
    w = jnp.zeros((3, 3, 2, 1)).at[1, 1, 0, 0].set(1.0)  # pick center, channel 0
    out = M.conv2d(x, w, jnp.zeros((1,)), relu=False)
    np.testing.assert_allclose(np.asarray(out[..., 0]), np.asarray(x[..., 0]))


@pytest.mark.parametrize("arch", ["vgg16", "zf"])
@pytest.mark.parametrize("batch", [1, 3])
def test_forward_output_shape(arch, batch):
    params = M.init_params(arch)
    x = jnp.zeros((batch, 64, 64, 3))
    out = M.forward(arch, params, x)
    assert out.shape == M.output_shape(arch, batch)


@pytest.mark.parametrize("arch", ["vgg16", "zf"])
def test_init_params_deterministic(arch):
    a = M.init_params(arch, seed=0)
    b = M.init_params(arch, seed=0)
    c = M.init_params(arch, seed=1)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
    assert any(
        not np.array_equal(np.asarray(pa), np.asarray(pc)) for pa, pc in zip(a, c)
    )


@pytest.mark.parametrize("arch", ["vgg16", "zf"])
def test_forward_finite_and_nonconstant(arch):
    rng = np.random.default_rng(9)
    params = M.init_params(arch)
    x = jnp.asarray(rng.random((2, 64, 64, 3)), jnp.float32)
    out = np.asarray(M.forward(arch, params, x))
    assert np.isfinite(out).all()
    assert out.std() > 0


def test_flops_per_frame_sane():
    v = M.flops_per_frame("vgg16")
    z = M.flops_per_frame("zf")
    assert v > z > 0  # VGG is the heavier program, as in the paper


@pytest.mark.parametrize("arch", ["vgg16", "zf"])
def test_make_jit_runs_and_matches_forward(arch):
    fn, specs = M.make_jit(arch, 1)
    params = M.init_params(arch)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.random((1, 64, 64, 3)), jnp.float32)
    (out,) = fn(*params, x)
    want = M.forward(arch, params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert len(specs) == len(params) + 1


def test_unknown_arch_raises():
    with pytest.raises(ValueError):
        M.init_params("resnet")
