"""AOT path: HLO text emission, params export, manifest integrity."""

import json
import os
import struct

import numpy as np
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def out_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    aot.main(["--out-dir", str(d), "--models", "zf", "--batches", "1,2"])
    return str(d)


def test_manifest_contents(out_dir):
    with open(os.path.join(out_dir, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    assert man["input_size"] == M.INPUT_SIZE
    assert len(man["models"]) == 2
    for entry in man["models"]:
        assert entry["name"] == "zf"
        assert entry["input_shape"] == [entry["batch"], 64, 64, 3]
        assert entry["output_shape"] == list(M.output_shape("zf", entry["batch"]))
        assert os.path.exists(os.path.join(out_dir, entry["hlo"]))
        assert os.path.exists(os.path.join(out_dir, entry["params_bin"]))


def test_hlo_text_is_parseable_hlo(out_dir):
    with open(os.path.join(out_dir, "zf_b1.hlo.txt")) as f:
        text = f.read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # params are inputs, not baked constants: expect one parameter per weight + x
    nparams = len(M.param_shapes("zf")) + 1
    assert text.count("parameter(") >= nparams


def test_params_bin_size_and_values(out_dir):
    params = M.init_params("zf", seed=0)
    want = np.concatenate([np.asarray(p, "<f4").ravel() for p in params])
    with open(os.path.join(out_dir, "zf.params.bin"), "rb") as f:
        raw = f.read()
    got = np.frombuffer(raw, "<f4")
    assert got.size == want.size == sum(int(np.prod(s)) for s in M.param_shapes("zf"))
    np.testing.assert_array_equal(got, want)


def test_relower_is_deterministic(tmp_path):
    e1 = aot.lower_model("zf", 1, str(tmp_path))
    t1 = open(tmp_path / "zf_b1.hlo.txt").read()
    e2 = aot.lower_model("zf", 1, str(tmp_path))
    t2 = open(tmp_path / "zf_b1.hlo.txt").read()
    assert e1["hlo_chars"] == e2["hlo_chars"]
    assert t1 == t2


def test_flops_recorded(out_dir):
    with open(os.path.join(out_dir, "manifest.json")) as f:
        man = json.load(f)
    for entry in man["models"]:
        assert entry["flops_per_frame"] == M.flops_per_frame(entry["name"])
