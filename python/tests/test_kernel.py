"""L1 correctness: Pallas matmul kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/epilogue flags; every case asserts allclose
against ref.matmul_ref. This is the core correctness signal for the artifacts
shipped to the Rust runtime.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, block_report, vmem_bytes, mxu_utilization, VMEM_BUDGET
from compile.kernels.ref import matmul_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    relu=st.booleans(),
    bias=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_shapes(m, k, n, relu, bias, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k), jnp.float32)
    w = _rand(rng, (k, n), jnp.float32)
    b = _rand(rng, (n,), jnp.float32) if bias else None
    got = matmul(x, w, b, relu=relu)
    want = matmul_ref(x, w, b, relu=relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    m=st.integers(4, 40),
    k=st.integers(4, 40),
    n=st.integers(4, 40),
)
def test_matmul_dtype_inputs_accumulate_f32(dtype, m, k, n):
    rng = np.random.default_rng(42)
    x = _rand(rng, (m, k), dtype)
    w = _rand(rng, (k, n), dtype)
    got = matmul(x, w)
    want = matmul_ref(x, w)
    assert got.dtype == jnp.float32
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("bm,bk,bn", [(8, 8, 8), (16, 32, 8), (32, 16, 64), (128, 128, 128)])
def test_matmul_explicit_blocks(bm, bk, bn):
    rng = np.random.default_rng(7)
    x = _rand(rng, (50, 37), jnp.float32)
    w = _rand(rng, (37, 29), jnp.float32)
    b = _rand(rng, (29,), jnp.float32)
    got = matmul(x, w, b, relu=True, bm=bm, bk=bk, bn=bn)
    want = matmul_ref(x, w, b, relu=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_matmul_rejects_bad_shapes():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((6, 3))
    with pytest.raises(ValueError):
        matmul(x, w)
    with pytest.raises(ValueError):
        matmul(jnp.zeros((4,)), w)
    with pytest.raises(ValueError):
        matmul(jnp.zeros((4, 6)), jnp.zeros((6, 3)), jnp.zeros((5,)))


def test_matmul_relu_clamps_negative():
    x = -jnp.ones((8, 8))
    w = jnp.eye(8)
    out = matmul(x, w, relu=True)
    assert float(jnp.min(out)) == 0.0


def test_matmul_zero_bias_equals_no_bias():
    rng = np.random.default_rng(3)
    x = _rand(rng, (17, 23), jnp.float32)
    w = _rand(rng, (23, 11), jnp.float32)
    a = matmul(x, w)
    b = matmul(x, w, jnp.zeros((11,)))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


# --- static TPU estimates ---------------------------------------------------

def test_default_blocks_fit_vmem():
    rep = block_report(128, 128, 128)
    assert rep["fits_vmem"]
    assert rep["vmem_bytes"] == vmem_bytes(128, 128, 128)
    # 2*(64k+64k+512)+64k bytes * 4 -> well under 16 MiB
    assert rep["vmem_frac"] < 0.1


def test_mxu_utilization_native_tile_is_full():
    assert mxu_utilization(128, 8, 128) == 1.0
    assert mxu_utilization(128, 128, 128) == 1.0


def test_mxu_utilization_penalizes_ragged_blocks():
    assert mxu_utilization(100, 8, 128) < 1.0
    assert mxu_utilization(128, 7, 128) < 1.0


@given(
    bm=st.integers(8, 256), bk=st.integers(8, 256), bn=st.integers(8, 256)
)
@settings(max_examples=50, deadline=None)
def test_vmem_bytes_monotone(bm, bk, bn):
    base = vmem_bytes(bm, bk, bn)
    assert vmem_bytes(bm + 8, bk, bn) > base
    assert vmem_bytes(bm, bk + 8, bn) > base
    assert vmem_bytes(bm, bk, bn + 8) > base
    assert 0.0 < mxu_utilization(bm, bk, bn) <= 1.0
