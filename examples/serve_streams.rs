//! End-to-end driver (EXPERIMENTS.md §RT): plan a ten-camera CAM²-style
//! workload, then actually serve it — synthetic frames are generated at each
//! camera's rate, routed to their planned (simulated) instances, dynamically
//! batched, and analyzed by the AOT-compiled VGG16/ZF detectors running on
//! the PJRT CPU client. Reports latency, throughput, batching, and cost.
//!
//! Run: `cargo run --release --offline --example serve_streams`
//!      (requires `make artifacts` first)

use camflow::bench::Table;
use camflow::cameras::{camera_at, StreamRequest};
use camflow::catalog::Catalog;
use camflow::coordinator::{Planner, PlannerConfig};
use camflow::geo::cities;
use camflow::profiles::{Program, Resolution};
use camflow::server::{serve, ServeConfig};
use camflow::util::fmt_usd;

fn workload() -> Vec<StreamRequest> {
    // Ten cameras, mirroring the paper's evaluation mix: a few VGG16 monitors
    // at low rates plus ZF trackers at higher rates.
    let cams = [
        ("New York", cities::NEW_YORK, Resolution::HD720),
        ("Chicago", cities::CHICAGO, Resolution::VGA),
        ("Houston", cities::HOUSTON, Resolution::VGA),
        ("West Lafayette", cities::WEST_LAFAYETTE, Resolution::XGA),
        ("Los Angeles", cities::LOS_ANGELES, Resolution::VGA),
        ("London", cities::LONDON, Resolution::HD720),
        ("Paris", cities::PARIS, Resolution::VGA),
        ("Tokyo", cities::TOKYO, Resolution::VGA),
        ("Singapore", cities::SINGAPORE, Resolution::XGA),
        ("Sydney", cities::SYDNEY, Resolution::VGA),
    ];
    cams.iter()
        .enumerate()
        .map(|(i, (city, loc, res))| {
            let (program, fps) = if i % 3 == 0 {
                (Program::Vgg16, 0.5)
            } else {
                (Program::Zf, 2.0)
            };
            StreamRequest::new(camera_at(i as u64, city, *loc, *res, 30.0), program, fps)
        })
        .collect()
}

fn main() -> camflow::Result<()> {
    let artifacts = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    let requests = workload();

    // Plan with GCL: location-aware, exact packing.
    let planner = Planner::new(Catalog::builtin(), PlannerConfig::gcl());
    let plan = planner.plan(&requests)?;
    println!(
        "plan: {} instances ({} CPU, {} GPU), {}/h, {} degraded streams",
        plan.instances.len(),
        plan.non_gpu,
        plan.gpu,
        fmt_usd(plan.cost_per_hour),
        plan.degraded.len()
    );
    for inst in &plan.instances {
        println!(
            "  {} — {} streams: {}",
            inst.label,
            inst.streams.len(),
            inst.streams
                .iter()
                .map(|&s| requests[s].label())
                .collect::<Vec<_>>()
                .join(", ")
        );
    }

    // Serve 60 virtual seconds at 20x compression (~3 s wall-clock of frames
    // plus engine compile time).
    let cfg = ServeConfig {
        artifacts_dir: artifacts.into(),
        duration_s: 60.0,
        time_scale: 20.0,
        batch_window_ms: 25,
        queue_capacity: 256,
        seed: 42,
    };
    let fps = plan.delivered_fps(&requests);
    let expected_fps: f64 = fps.iter().sum();
    println!("\nserving {}s virtual at {}x ({} streams, Σfps={expected_fps:.2})...", cfg.duration_s, cfg.time_scale, requests.len());
    let report = serve(&plan, &requests, &fps, &cfg)?;

    let mut t = Table::new(&["Instance", "Streams", "Analyzed", "Dropped", "Mean batch", "Infer ms", "E2E p50 ms", "E2E p99 ms"]);
    for i in &report.instances {
        t.row(&[
            i.label.clone(),
            i.streams.to_string(),
            i.frames_analyzed.to_string(),
            i.frames_dropped.to_string(),
            format!("{:.2}", i.mean_batch),
            format!("{:.2}", i.infer_mean_ms),
            format!("{:.2}", i.e2e_p50_ms),
            format!("{:.2}", i.e2e_p99_ms),
        ]);
    }
    t.print();
    println!(
        "\nthroughput {:.2} virtual fps (target {:.2}), drop rate {:.1}%, detections {}, cost {}/h, wall {:.1}s",
        report.virtual_throughput_fps,
        expected_fps,
        report.drop_rate() * 100.0,
        report.detections,
        fmt_usd(report.plan_cost_per_hour),
        report.real_duration_s
    );

    // Success criteria for EXPERIMENTS.md: all layers composed; most frames
    // analyzed at the planned rate.
    assert!(report.total_frames_analyzed > 0, "no frames analyzed");
    assert!(report.drop_rate() < 0.5, "excessive drops");
    println!("\nOK: three-layer stack (Rust coordinator → HLO artifacts → Pallas matmul) served end-to-end.");
    Ok(())
}
