//! Location-aware planning over a worldwide camera fleet (the Fig-4/Fig-6
//! setting): shows the coverage-circle effect and compares NL / ARMVAC / GCL.
//!
//! Run: `cargo run --release --offline --example global_cameras`

use camflow::bench::Table;
use camflow::cameras::scenarios;
use camflow::catalog::Catalog;
use camflow::coordinator::{Planner, PlannerConfig};
use camflow::geo;
use camflow::util::fmt_usd;

fn main() -> camflow::Result<()> {
    let catalog = Catalog::builtin();

    // Part 1 — Fig 4: the six cameras and their coverage circles.
    println!("== Fig 4: coverage circles ==");
    let cams = scenarios::fig4_cameras();
    for fps in [20.0, 3.0] {
        let radius = geo::coverage_radius_km(fps);
        println!("\ndesired {fps} fps -> max RTT {:.0} ms -> radius {:.0} km", geo::rtt_budget_ms(fps), radius);
        let mut covered_by: Vec<Vec<&str>> = Vec::new();
        for cam in &cams {
            let regions: Vec<&str> = catalog
                .regions
                .iter()
                .filter(|r| geo::reachable(&cam.location, &r.location, fps))
                .map(|r| r.id)
                .collect();
            println!("  {:<12} reachable regions: {}", cam.city, regions.join(", "));
            covered_by.push(regions);
        }
    }

    // Part 2 — Fig 6 snapshot: NL / ARMVAC / GCL at a mid-band frame rate.
    println!("\n== Fig 6 snapshot: 30 cameras at 4 fps ==");
    let requests = scenarios::fig6_workload(30, 4.0, 1);
    let mut t = Table::new(&["Manager", "Instances", "Regions", "Cost $/h", "vs NL"]);
    let mut nl_cost = None;
    for (name, cfg) in [
        ("NL", PlannerConfig::nl()),
        ("ARMVAC", PlannerConfig::armvac()),
        ("GCL", PlannerConfig::gcl()),
    ] {
        let plan = Planner::new(catalog.clone(), cfg).plan(&requests)?;
        let base = *nl_cost.get_or_insert(plan.cost_per_hour);
        t.row(&[
            name.to_string(),
            plan.instances.len().to_string(),
            plan.regions_used().to_string(),
            format!("{:.3}", plan.cost_per_hour),
            format!("{:.0}%", (1.0 - plan.cost_per_hour / base) * 100.0),
        ]);
    }
    t.print();

    // Part 3 — where does GCL send the Tokyo cameras?
    println!("\n== GCL placements ==");
    let plan = Planner::new(catalog.clone(), PlannerConfig::gcl()).plan(&requests)?;
    for inst in plan.instances.iter().take(8) {
        let cities: Vec<String> = inst
            .streams
            .iter()
            .map(|&s| requests[s].camera.city.clone())
            .collect();
        println!(
            "  {} ({}) <- {}",
            inst.label,
            fmt_usd(inst.hourly_cost),
            cities.join(", ")
        );
    }
    if plan.instances.len() > 8 {
        println!("  ... and {} more instances", plan.instances.len() - 8);
    }
    Ok(())
}
