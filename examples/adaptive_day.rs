//! Adaptive resource management over a simulated day (the paper's runtime
//! adaptation experiment, cf. Kaseb et al. [14]): demand swings between
//! night (0.2 fps weather watching), day (1 fps), and rush hours (8 fps
//! object tracking); the manager re-plans hourly — incrementally, through
//! the staged pipeline's persistent caches — and the cloud simulator bills
//! the fleet. The `reuse` column shows how much of each re-plan was served
//! from cached stage artifacts.
//!
//! Run: `cargo run --release --offline --example adaptive_day`

use camflow::bench::Table;
use camflow::cameras::CameraDb;
use camflow::catalog::Catalog;
use camflow::cloudsim::CloudSim;
use camflow::coordinator::{adaptive::AdaptiveManager, Planner, PlannerConfig};
use camflow::profiles::Program;
use camflow::util::fmt_usd;

fn fps_for_hour(h: usize) -> f64 {
    match h % 24 {
        7..=9 | 16..=18 => 8.0, // rush hours: track moving objects
        22 | 23 | 0..=5 => 0.2, // night: weather/air-quality watching
        _ => 1.0,               // daytime baseline
    }
}

fn main() -> camflow::Result<()> {
    let catalog = Catalog::builtin();
    let planner = Planner::new(catalog.clone(), PlannerConfig::gcl());
    let mut mgr = AdaptiveManager::new(planner);
    let mut sim = CloudSim::new(catalog);

    let db = CameraDb::synthetic(12, 3);
    println!("{} cameras across {} cities\n", db.len(), {
        let mut cs: Vec<_> = db.cameras().iter().map(|c| c.city.clone()).collect();
        cs.sort();
        cs.dedup();
        cs.len()
    });

    let mut t = Table::new(&[
        "hour", "fps", "instances", "$/h", "+prov", "-term", "moved", "churn", "reuse",
    ]);
    let mut peak_rate = 0.0f64;
    let mut moved_total = 0usize;
    for h in 0..24 {
        let fps = fps_for_hour(h);
        let requests = db.workload(Program::Zf, fps);
        let report = mgr.replan(requests)?;
        let plan = mgr.current_plan().unwrap();
        sim.apply_plan(plan)?;
        sim.advance(3600.0);
        peak_rate = peak_rate.max(plan.cost_per_hour);
        moved_total += report.streams_moved;
        t.row(&[
            h.to_string(),
            fps.to_string(),
            plan.instances.len().to_string(),
            format!("{:.3}", plan.cost_per_hour),
            report.provision.iter().map(|(_, n)| n).sum::<usize>().to_string(),
            report.terminate.iter().map(|(_, n)| n).sum::<usize>().to_string(),
            report.streams_moved.to_string(),
            format!("{:.0}%", report.churn_ratio() * 100.0),
            format!("{:.0}%", report.pipeline.reuse_ratio() * 100.0),
        ]);
    }
    t.print();
    println!("\ntotal stream moves over the day (each one a reconnection): {moved_total}");

    let adaptive = sim.accrued_usd();
    let static_peak = peak_rate * 24.0;
    println!(
        "\nadaptive 24h cost: {}   static peak-provisioned: {}   saving: {:.0}%",
        fmt_usd(adaptive),
        fmt_usd(static_peak),
        (1.0 - adaptive / static_peak) * 100.0
    );
    // The paper's summary claim: "more than 50% cost can be saved".
    assert!(
        adaptive < 0.5 * static_peak,
        "adaptive should save >50% vs static peak provisioning"
    );
    println!("OK: adaptive management saves >50% vs static peak provisioning, as the paper claims.");
    Ok(())
}
