//! Quickstart: plan the paper's Fig-3 Scenario 1 with all three strategies.
//!
//! Run: `cargo run --release --offline --example quickstart`

use camflow::bench::Table;
use camflow::cameras::scenarios;
use camflow::catalog::Catalog;
use camflow::coordinator::{Planner, PlannerConfig};
use camflow::util::fmt_usd;

fn main() -> camflow::Result<()> {
    // The Fig-3 instance pool: the paper's $0.419 CPU box and $0.650 GPU box.
    let catalog =
        Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));

    let scenario = scenarios::fig3_scenario1();
    println!("{}: {} streams", scenario.name, scenario.requests.len());
    for r in &scenario.requests {
        println!(
            "  {} ({}, native {} fps)",
            r.label(),
            r.camera.resolution,
            r.camera.native_fps
        );
    }
    println!();

    let mut table = Table::new(&["Strategy", "Non-GPU", "GPU", "Hourly cost", "Savings"]);
    let configs = [
        ("ST1 (CPU only)", PlannerConfig::st1()),
        ("ST2 (GPU only)", PlannerConfig::st2()),
        ("ST3 (CPU+GPU packing)", PlannerConfig::st3()),
    ];
    let mut costs = Vec::new();
    for (name, cfg) in configs {
        let plan = Planner::new(catalog.clone(), cfg).plan(&scenario.requests)?;
        costs.push((name, plan.non_gpu, plan.gpu, plan.cost_per_hour));
    }
    let worst = costs.iter().map(|c| c.3).fold(0.0, f64::max);
    for (name, non_gpu, gpu, cost) in costs {
        table.row(&[
            name.to_string(),
            non_gpu.to_string(),
            gpu.to_string(),
            fmt_usd(cost),
            format!("{:.0}%", (1.0 - cost / worst) * 100.0),
        ]);
    }
    table.print();
    println!("\n(The paper's Fig 3, Scenario 1 row: ST1 4x non-GPU $1.676, ST2/ST3 1x GPU $0.650, 61% saving.)");
    Ok(())
}
